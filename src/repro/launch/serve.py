"""Serving driver: the paper's online path (Fig. 18) behind a batch API.

  PYTHONPATH=src python -m repro.launch.serve --engine infinity --n 10000
  PYTHONPATH=src python -m repro.launch.serve --engine ivf_flat --shards 2
  PYTHONPATH=src python -m repro.launch.serve --engine nsw --live \
      --delta-cap 512 --snapshot /tmp/idx

``SearchServer`` is registry-driven: any engine key from ``core/index``
(brute / ivf_flat / ivf_pq / nsw / infinity), optionally sharded over the
host's devices, behind one ``query`` method.  Query batches are padded up to
a fixed bucket size so each (bucket, k) pair compiles exactly once — the
static-shape discipline the TPU serving path needs.

``--live`` wraps the engine in the ``core/live`` subsystem: the server
gains ``upsert`` / ``delete`` / ``compact`` / ``snapshot`` operations, and
``stats()`` reports segment composition (frozen size, delta fill,
tombstones, generation) next to the latency percentiles so operators can
see compaction pressure building.  ``--snapshot PATH`` restores the index
from a ``core/store`` snapshot when one exists there, and writes one after
the run otherwise — restart without rebuild.

Filtered search: build the server with ``attrs={column: per-row values}``
and pass ``filter={...}`` (the ``core/filter`` dict sugar) to ``query`` /
``serve`` — every engine then answers only from predicate-passing rows.
``--filter JSON`` smoke-runs it against demo attribute columns, and
``--list-engines`` prints the registry so operators can discover engines
without reading source.

Quantized serving: ``--quant`` (``SearchServer(quant=True)``) adds the
reserved ``quant`` cfg key — the corpus is mirrored as per-dimension int8
codes (``core/quant``) and the scan engines (brute, ivf_flat, infinity's
rerank, the live delta) read 1 byte/dim on the first pass, exactly
reranking a pow2 shortlist in f32.  ``stats()`` reports the code-store
bytes next to memory/QPS so operators see the bandwidth trade.

Fault-tolerant serving (DESIGN.md §14): ``query(deadline_ms=...)`` runs a
per-request controller — remaining deadline maps to a shrinking comparison
budget (``core/backoff.degraded_budget``'s pow2 ladder, the paper's
anytime knob), transient faults are retried with capped exponential
backoff, and when a shard of a sharded index stays dead the request is
answered from the surviving shards with the failed shard masked out of the
merge.  Every answer is a ``ServedResult`` stamped ``degraded`` /
``shards_answered`` so callers can tell exact from best-effort.  The
server runs a SERVING -> DEGRADED -> RECOVERING health state machine:
``snapshot_dir=`` keeps a sha256-verified last-good snapshot that a failed
engine swap auto-restores, and ``stats()`` surfaces health plus
fault/retry/recovery counters.  ``chaos=`` (``--chaos JSON``) arms a
``core/chaos.FaultPlan`` so all of it can be scripted deterministically;
``--deadline-ms`` drives the degraded path from the CLI.

For LM serving, ``make_prefill_step`` / ``make_decode_step`` in
train/train_step.py are the hardware entry points exercised by the dry-run
(prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import math
import os
import shutil
import threading
import time
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import backoff as backoff_lib
from repro.core import chaos as chaos_lib
from repro.core import index as index_lib
from repro.core import probes as probes_lib
from repro.core import telemetry as telem
from repro.data import synthetic


def _bucket(n: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor) — the padded static batch."""
    from repro.core.scan import pow2ceil

    return max(floor, pow2ceil(n))


class LatencyRing:
    """Bounded per-batch latency window — replaces the unbounded
    ``_lat_s`` list, which grew one float per recorded batch forever under
    sustained traffic.  Percentiles/QPS are computed over the most recent
    ``cap`` batches (the operator's rolling window); lifetime totals live
    in separate counters on the server, so ``stats()['batches']`` keeps
    its every-batch-ever meaning while memory stays flat (tested at 100k
    appends in tests/test_telemetry.py)."""

    def __init__(self, cap: int = 4096):
        self.cap = int(cap)
        self._lat = np.zeros((self.cap,), np.float64)
        self._nq = np.zeros((self.cap,), np.int64)
        self._pos = 0
        self._len = 0

    def append(self, lat_s: float, n_queries: int) -> None:
        self._lat[self._pos] = lat_s
        self._nq[self._pos] = n_queries
        self._pos = (self._pos + 1) % self.cap
        self._len = min(self._len + 1, self.cap)

    def __len__(self) -> int:
        return self._len

    def window(self) -> tuple[np.ndarray, np.ndarray]:
        """(latencies_s, batch_sizes) of the window, oldest-truncated."""
        if self._len < self.cap:
            return self._lat[: self._len], self._nq[: self._len]
        return self._lat, self._nq


class ServedResult(NamedTuple):
    """A ``SearchResult`` plus the serving-layer provenance a caller needs
    to tell an exact answer from a best-effort one (DESIGN.md §14).

    ``degraded`` is True when any shard was masked out of the merge —
    ``idx``/``dist`` then cover only the ``shards_answered`` surviving
    shards' rows.  ``retries`` counts transparent re-attempts this request
    absorbed; ``deadline_met`` is False when the answer returned after its
    deadline had already lapsed (the budget floor bounds how small the
    search can shrink).

    Overload provenance (DESIGN.md §18, set by ``launch/runtime``):
    ``queue_ms`` is the time this request waited in the admission queue
    before its batch dispatched (0 for direct ``query`` calls);
    ``outcome`` distinguishes a computed answer (``"ok"``) from an explicit
    shed — ``"shed_expired"`` (deadline lapsed before compute),
    ``"shed_breaker"`` (circuit breaker open, fast-failed) or
    ``"shed_shutdown"`` (still queued when the runtime stopped).  Shed
    results carry idx=-1 rows and zero comparisons: never a silent
    drop."""

    idx: np.ndarray  # (B, k) int32, -1 = no result
    dist: np.ndarray  # (B, k) f32 ascending
    comparisons: np.ndarray  # (B,) int32
    degraded: bool = False
    shards_answered: int = 1
    shards_total: int = 1
    retries: int = 0
    deadline_met: bool = True
    queue_ms: float = 0.0
    outcome: str = "ok"


@dataclasses.dataclass
class FaultPolicy:
    """The serving controller's knobs (``SearchServer(policy=...)``).

    ``max_retries`` bounds transparent re-attempts per request;
    backoff between them is capped exponential (``core/backoff``).
    ``give_up_frac``: once less than this fraction of the deadline
    remains, a failing shard is masked out instead of retried — the
    request's remaining time goes to computing an answer, not to hoping.
    ``budget_floor`` floors the deadline->budget ladder so even a nearly
    expired request runs a minimal real search."""

    max_retries: int = 2
    backoff_base_s: float = 0.005
    backoff_cap_s: float = 0.05
    give_up_frac: float = 0.25
    budget_floor: int = 8


#: the health state machine's states (DESIGN.md §14): SERVING — full
#: answers; DEGRADED — answering from surviving shards / awaiting repair;
#: RECOVERING — a restore of the last good snapshot is in flight.
HEALTH_STATES = ("SERVING", "DEGRADED", "RECOVERING")


class SearchServer:
    """Build once, answer batched queries — the deployable object.

    engine / shards select any registered index; ``swap`` rebuilds a
    different engine over the same corpus (hot-swap).  ``query`` pads the
    incoming batch to a power-of-two bucket (repeating the last row) and
    slices the answer back, so arbitrary client batch sizes never trigger
    fresh compilation beyond one per bucket.

    Fault tolerance (DESIGN.md §14): ``chaos=`` arms a scripted
    ``core/chaos.FaultPlan`` (or its ``{"seed":..., "rules":[...]}`` dict
    sugar) on the serving index; ``query(deadline_ms=...)`` degrades
    instead of dying — retry with capped backoff, shrink the comparison
    budget as the deadline drains, answer from surviving shards when one
    stays dead — and returns a ``ServedResult`` flagged ``degraded`` /
    ``shards_answered``.  ``snapshot_dir=`` keeps a sha256-verified
    last-good snapshot: a failed ``swap`` auto-restores it (health walks
    SERVING -> DEGRADED -> RECOVERING -> SERVING), and ``stats()`` reports
    ``health`` plus the fault/retry/recovery counters.
    """

    #: serving defaults applied when no cfg is given — the bounded two-stage
    #: operating point (budget/rerank land in the engine's search defaults
    #: where applicable); pass cfg={} to get the engine's own raw defaults.
    DEFAULT_BUDGET = 256
    DEFAULT_RERANK = 96

    def __init__(self, corpus, *, engine: str = "infinity", shards: int = 1,
                 cfg: Optional[dict] = None, live: bool = False,
                 delta_cap: int = 1024, attrs: Optional[dict] = None,
                 quant: bool = False, chaos=None,
                 snapshot_dir: Optional[str] = None,
                 policy: Optional[FaultPolicy] = None,
                 probe=None):
        self.corpus = jnp.asarray(corpus, jnp.float32)
        self.attr_values = dict(attrs) if attrs else None
        self.quant = bool(quant)
        self.chaos = None if chaos is None else chaos_lib.FaultPlan.from_cfg(chaos)
        self.policy = policy or FaultPolicy()
        self.snapshot_dir = snapshot_dir
        # online recall probe (DESIGN.md §17): float rate / dict / ProbeConfig
        self._probe = None if probe is None else probes_lib.RecallProbe(probe)
        self._probe_pending: list = []
        self._probe_raw: list = []
        self._probe_raw_q = 0
        self._probe_key = None
        self._probe_filter = None
        self._init_fault_state()
        self.swap(engine, shards=shards, cfg=cfg, live=live, delta_cap=delta_cap)
        if snapshot_dir is not None:
            self._save_good_snapshot()

    def _init_fault_state(self) -> None:
        self.health = "SERVING"
        self.health_log: list[str] = ["SERVING"]
        self._dead_shards: set[int] = set()
        self._last_good: Optional[str] = None
        self._snap_seq = 0
        # one lock for every cross-thread mutable serving stat: the async
        # runtime (DESIGN.md §18) drives ingress from many worker threads,
        # and a plain dict `+= 1` is a read-modify-write that loses
        # increments under races — counters, health transitions and the
        # latency record all mutate under this RLock (re-entrant: _heal
        # counts faults while walking health)
        self._state_lock = threading.RLock()
        self.fault_counters = {
            "faults": 0, "retries": 0, "degraded_queries": 0,
            "recoveries": 0, "snapshot_restores": 0, "snapshot_corrupt": 0,
            "deadline_misses": 0, "quality_breaches": 0,
        }

    def _count_fault(self, key: str, n: int = 1) -> None:
        """Locked fault-counter increment — the only writer of
        ``fault_counters`` (tested for lost updates under concurrent
        queries in tests/test_runtime.py)."""
        with self._state_lock:
            self.fault_counters[key] += n

    def _set_health(self, state: str) -> None:
        assert state in HEALTH_STATES, state
        with self._state_lock:
            if state != self.health:
                telem.count("health_transitions_total",
                            **{"from": self.health, "to": state})
                self.health = state
                self.health_log.append(state)

    # ---------------------------------------------------------- self-healing
    def _save_good_snapshot(self) -> Optional[str]:
        """Write (and sha256-verify) a rotating last-good snapshot under
        ``snapshot_dir``.  A write the chaos plan corrupted fails
        verification and is discarded — the previous good snapshot stays
        the restore point; one clean retry runs because the plan's draws
        advance per call."""
        if self.snapshot_dir is None:
            return None
        from repro.core import store as store_lib

        for _ in range(2):
            self._snap_seq += 1
            path = os.path.join(self.snapshot_dir, f"snap-{self._snap_seq:04d}")
            try:
                store_lib.save(self.index, path)
                store_lib.verify(path)
            except store_lib.SnapshotCorruption:
                self._count_fault("snapshot_corrupt")
                shutil.rmtree(path, ignore_errors=True)
                continue
            old, self._last_good = self._last_good, path
            if old and old != path:
                shutil.rmtree(old, ignore_errors=True)
            return path
        return self._last_good

    def _heal(self, why: str) -> bool:
        """DEGRADED -> RECOVERING -> SERVING: restore the last good
        snapshot (sha256-verified on load).  Falls back to the in-memory
        index — intact by construction, since every mutation publishes
        atomically — when no verified snapshot exists.  Returns True when
        a snapshot restore happened."""
        from repro.core import store as store_lib

        self._set_health("DEGRADED")
        self._set_health("RECOVERING")
        restored = False
        if self._last_good is not None:
            try:
                self.index = store_lib.load(self._last_good)
                if self.chaos is not None:
                    index_lib.attach_chaos(self.index, self.chaos)
                self._count_fault("snapshot_restores")
                restored = True
            except store_lib.SnapshotCorruption:
                self._count_fault("snapshot_corrupt")
        if restored or getattr(self, "index", None) is not None:
            self._count_fault("recoveries")
            self._set_health("SERVING")
        return restored

    def swap(self, engine: str, *, shards: int = 1, cfg: Optional[dict] = None,
             live: Optional[bool] = None, delta_cap: Optional[int] = None,
             quant: Optional[bool] = None) -> None:
        """(Re)build the serving index over the held corpus.  ``live``/
        ``delta_cap``/``quant`` (and the attribute columns given at
        construction) stick across swaps unless overridden."""
        if getattr(self, "corpus", None) is None:
            raise RuntimeError(
                "this server was restored from a snapshot that carries no "
                "corpus (sharded engine state); build a fresh SearchServer "
                "to swap engines"
            )
        if cfg is None:
            cfg = default_cfg(engine, budget=self.DEFAULT_BUDGET,
                              rerank=self.DEFAULT_RERANK)
        self.live = bool(live) if live is not None else getattr(self, "live", False)
        if quant is not None:
            self.quant = bool(quant)
        else:
            self.quant = getattr(self, "quant", False)
        if delta_cap is not None:
            self.delta_cap = int(delta_cap)
        else:
            self.delta_cap = getattr(self, "delta_cap", 1024)
        t0 = time.perf_counter()
        if shards > 1:
            inner, inner_cfg = "sharded", {
                "engine": engine, "shards": shards, "engine_cfg": dict(cfg or {}),
            }
        else:
            inner, inner_cfg = engine, dict(cfg or {})
        attrs = getattr(self, "attr_values", None)
        try:
            if self.live:
                top_cfg = {"engine": inner, "engine_cfg": inner_cfg,
                           "delta_cap": self.delta_cap}
                if attrs:
                    top_cfg["attrs"] = attrs
                if self.quant:
                    top_cfg["quant"] = True
                if self.chaos is not None:
                    top_cfg["chaos"] = self.chaos
                built = index_lib.build("live", self.corpus, top_cfg)
            else:
                if attrs:
                    inner_cfg = dict(inner_cfg) | {"attrs": attrs}
                if self.quant:
                    inner_cfg = dict(inner_cfg) | {"quant": True}
                if self.chaos is not None:
                    inner_cfg = dict(inner_cfg) | {"chaos": self.chaos}
                built = index_lib.build(inner, self.corpus, inner_cfg)
        except chaos_lib.FaultError:
            self._count_fault("faults")
            self._heal(f"swap({engine!r}) build poisoned")
            raise
        self.index = built
        self.engine = engine
        self.shards = shards
        self._dead_shards.clear()
        self.build_s = time.perf_counter() - t0
        self._lat = LatencyRing()  # bounded per-batch latency window
        self._queries = 0
        self._batches = 0
        self._buckets_seen: set = set()  # (engine, bucket, k) jit-cache keys
        if getattr(self, "_probe", None) is not None:
            # fresh engine, fresh estimate: the window must never mix
            # engines, and a rewound ordinal stream keeps the probe set
            # reproducible per (engine, traffic) pair
            self._probe.reset()
        self._probe_pending = []
        self._probe_raw = []
        self._probe_raw_q = 0
        self._probe_key = None
        self._probe_filter = None

    @classmethod
    def restore(cls, path: str) -> "SearchServer":
        """Rebuild a server from a ``core/store`` snapshot — no index build.

        The corpus is recovered where the index carries it (live indexes
        report their logical view; single-device engines hold X); sharded
        snapshots serve fine but hold no rebuildable corpus, so a later
        ``swap()`` raises instead of building on nothing.
        """
        from repro.core import store as store_lib

        index = store_lib.load(path)
        srv = object.__new__(cls)
        srv.index = index

        def unwrap(idx):
            """(engine label, shard count) through live/sharded wrappers."""
            if idx.registry_name == "sharded":
                return idx.engine, idx.n // idx.shard_size
            return getattr(idx, "registry_name", "?"), 1

        srv.live = index.registry_name == "live"
        srv.quant = getattr(index, "quant", None) is not None
        srv.delta_cap = getattr(index, "delta_cap", 1024)
        if srv.live:
            if index.engine == "sharded":
                srv.engine = index.engine_cfg.get("engine", "sharded")
                srv.shards = int(index.engine_cfg.get("shards", 2))
            else:
                srv.engine, srv.shards = index.engine, 1
            corpus = index.corpus()
        else:
            srv.engine, srv.shards = unwrap(index)
            corpus = getattr(index, "X", None)
        srv.corpus = None if corpus is None else jnp.asarray(corpus, jnp.float32)
        # carry restored attribute columns across future swap() rebuilds
        # (live stores are slot-aligned: gather the alive slots, whose
        # order is exactly corpus()'s logical row order)
        store = getattr(index, "attrs", None)
        srv.attr_values = None
        if store is not None and srv.corpus is not None:
            if srv.live:
                alive = np.where(index.slot_to_logical() >= 0)[0]
                srv.attr_values = store.to_values(alive)
            else:
                srv.attr_values = store.to_values(
                    np.arange(int(srv.corpus.shape[0]))
                )
        srv.build_s = 0.0
        srv._lat = LatencyRing()
        srv._queries = 0
        srv._batches = 0
        srv._buckets_seen = set()
        srv.chaos = None
        srv.policy = FaultPolicy()
        srv.snapshot_dir = None
        srv._probe = None
        srv._probe_pending = []
        srv._probe_raw = []
        srv._probe_raw_q = 0
        srv._probe_key = None
        srv._probe_filter = None
        srv._init_fault_state()
        return srv

    def query(self, batch, k: int = 10, *, budget: Optional[int] = None,
              filter: Optional[dict] = None, record: bool = True,
              deadline_ms: Optional[float] = None) -> ServedResult:
        """Answer one query batch; returns a host-side ``ServedResult``.

        ``filter`` — a ``core/filter`` predicate spec (dict sugar: ``{"shop":
        {"isin": [...]}, "price": {"range": [lo, hi]}}``) evaluated against
        the attribute columns the server was built with; the answer then
        only contains passing rows.  ``record=False`` keeps a warm-up/
        compile call out of the stats() latency record.

        ``deadline_ms`` arms the per-request degradation controller
        (DESIGN.md §14): the comparison budget shrinks with the remaining
        deadline on a pow2 ladder, transient faults retry with capped
        exponential backoff while time allows, and a shard that stays dead
        is masked out of the merge so the survivors still answer — the
        result is then stamped ``degraded`` with ``shards_answered`` <
        ``shards_total``.  Without a deadline the same retry/mask logic
        runs, just without budget shrinking."""
        raw_batch = batch  # pre-device view: the probe buffers from this
        arr = np.asarray(batch, np.float32)
        B = arr.shape[0]
        if B == 0:
            raise ValueError("empty query batch")
        Bp = _bucket(B)
        with telem.span("pad", engine=self.engine, bucket=Bp):
            # pad with copies of the last row: static shapes for jit.  The
            # pad runs in numpy ON PURPOSE — a jnp.concatenate here is
            # itself an XLA program compiled per (B, Bp-B) shape pair, so
            # under the async runtime (whose live batch sizes vary freely,
            # DESIGN.md §18) every previously unseen raw size B paid a
            # ~50ms compile inside the serving path.  Host-side padding
            # keeps the device cache keyed by Bp alone.
            if Bp > B:
                arr = np.concatenate(
                    [arr, np.broadcast_to(arr[-1:], (Bp - B, arr.shape[1]))]
                )
            batch = jnp.asarray(arr)
        # serving-layer jit-cache accounting per (engine, bucket, k): a
        # fresh key means this call pays a compile (the per-knob caches
        # below — ShardedIndex._jitted, the engines' jitted fns — miss too)
        bkey = (self.engine, Bp, int(k))
        with self._state_lock:
            fresh = bkey not in self._buckets_seen
            if fresh:
                self._buckets_seen.add(bkey)
        if fresh:
            telem.count("jit_cache_misses_total", engine=self.engine,
                        scope="server", bucket=Bp)
        else:
            telem.count("jit_cache_hits_total", engine=self.engine,
                        scope="server", bucket=Bp)
        pol = self.policy
        dl = backoff_lib.Deadline(deadline_ms)
        S = max(1, int(self.shards)) if not self.live else 1
        excluded: set[int] = set()
        retries = 0
        t0 = time.perf_counter()
        while True:
            eff_budget = backoff_lib.degraded_budget(
                budget, dl.fraction_left(), floor=pol.budget_floor)
            kw = {"budget": eff_budget, "filter": filter}
            if excluded:
                kw["shard_alive"] = tuple(s not in excluded for s in range(S))
            try:
                # the dispatch span closes (error=True) when a chaos fault
                # escapes the engine — the exception-path guarantee
                # tests/test_telemetry.py pins down
                with telem.span("dispatch", engine=self.engine, bucket=Bp):
                    idx, dist, comps = self.index.search(batch, k=k, **kw)
                    jax.block_until_ready(idx)
                break
            except chaos_lib.ShardFault as e:
                self._count_fault("faults")
                telem.count("faults_total", engine=self.engine, kind="shard")
                known_dead = e.shard in self._dead_shards
                out_of_time = dl.fraction_left() < pol.give_up_frac
                if known_dead or out_of_time or retries >= pol.max_retries:
                    # mask the shard out and answer from the survivors —
                    # the request's remaining time goes to computing an
                    # answer, not to hoping the shard comes back
                    excluded.add(e.shard)
                    if len(excluded) >= S:
                        raise  # every shard down: nothing left to answer from
                    self._dead_shards.add(e.shard)
                    self._set_health("DEGRADED")
                    continue  # immediately, no sleep
                retries += 1
                self._count_fault("retries")
                telem.count("retries_total", engine=self.engine, kind="shard")
                time.sleep(backoff_lib.backoff_s(
                    retries - 1, base_s=pol.backoff_base_s,
                    cap_s=pol.backoff_cap_s))
            except chaos_lib.TransientFault:
                self._count_fault("faults")
                telem.count("faults_total", engine=self.engine,
                            kind="transient")
                if retries >= pol.max_retries or dl.expired():
                    raise  # the plan scripted a fault storm; surface it
                retries += 1
                self._count_fault("retries")
                telem.count("retries_total", engine=self.engine,
                            kind="transient")
                time.sleep(backoff_lib.backoff_s(
                    retries - 1, base_s=pol.backoff_base_s,
                    cap_s=pol.backoff_cap_s))
        if not excluded and self._dead_shards:
            # a full, clean answer proves every shard is back: self-heal
            self._dead_shards.clear()
            self._count_fault("recoveries")
            self._set_health("SERVING")
        degraded = bool(excluded)
        if degraded:
            self._count_fault("degraded_queries")
            telem.count("degraded_total", engine=self.engine)
        deadline_met = not dl.expired()
        if not deadline_met:
            self._count_fault("deadline_misses")
            telem.count("deadline_misses_total", engine=self.engine)
        dt = time.perf_counter() - t0
        if record:
            with self._state_lock:
                self._lat.append(dt, B)
                self._queries += B
                self._batches += 1
            telem.observe("search_latency", dt, engine=self.engine,
                          shards=S)
            telem.count("queries_total", B, engine=self.engine)
            if deadline_ms is not None:
                # remaining fraction of the deadline when the answer landed
                # — the headroom the degradation ladder keys off
                telem.set_gauge("deadline_slack_frac", dl.fraction_left(),
                                engine=self.engine)
        res = ServedResult(
            np.asarray(idx)[:B], np.asarray(dist)[:B], np.asarray(comps)[:B],
            degraded=degraded, shards_answered=S - len(excluded),
            shards_total=S, retries=retries, deadline_met=deadline_met,
        )
        if record and self._probe is not None:
            # observe-only: the answer and its recorded latency are final
            # before the probe sees anything (DESIGN.md §17)
            self._probe_observe(raw_batch, res.idx, k, filter)
        return res

    # -------------------------------------------------- online recall probes
    def _probe_observe(self, batch, served_idx, k, filter) -> None:
        """Shadow path entry (DESIGN.md §17): enqueue this recorded batch
        for deferred sampling.  The per-batch cost must be a list append —
        even one numpy call right after engine work pays ~35us of cold
        caches, which is real p50 tax at 1% sampling.  ``_drain_raw``
        does the actual sampling every few batches (amortizing that
        cold-start), sized so high probe rates still flush as eagerly as
        the synchronous form did.  The enqueued query array is the
        caller's — the server assumes it is not mutated in flight (the
        usual zero-copy serving contract).  Never raises into serving — a
        probe failure is a counted telemetry event, not an outage."""
        probe = self._probe
        try:
            self._probe_raw.append((batch, served_idx, int(k), filter))
            self._probe_raw_q += served_idx.shape[0]
            if (len(self._probe_raw) >= 8
                    or probe.cfg.rate * self._probe_raw_q
                    >= probe.cfg.flush_at):
                self._drain_raw()
        except Exception:
            telem.count("probe_errors_total", engine=self.engine)

    def _drain_raw(self) -> None:
        """Sample + buffer every enqueued batch (FIFO, so query ordinals
        land exactly as synchronous per-batch sampling would), flushing
        ground truth whenever the buffer fills or the view changes.  One
        live generation holds for the whole queue: every mutation drains
        through ``flush_probes`` before touching the corpus."""
        raw, self._probe_raw = self._probe_raw, []
        self._probe_raw_q = 0
        probe = self._probe
        gen = self.index.stats()["generation"] if self.live else None
        for batch, served_idx, k, filter in raw:
            B = served_idx.shape[0]
            pick = probe.sample_indices(B)
            if len(pick):
                # one flush = one ground-truth view: same filter, same live
                # generation, same engine — anything else flushes first
                key = (probes_lib.view_key(filter), gen, self.engine)
                if self._probe_pending and key != self._probe_key:
                    self._flush_probes()
                self._probe_key = key
                self._probe_filter = filter
                # batch is the caller's pre-device array (free when it is
                # already host f32 — a device round trip here costs ~100us
                # per sampled batch)
                Qs = np.asarray(batch, np.float32)[:B][pick]
                kp = min(probe.cfg.k, int(k))
                srv = np.asarray(served_idx)[pick][:, :kp]
                for row_q, row_i in zip(Qs, srv):
                    self._probe_pending.append((row_q, row_i))
            if len(self._probe_pending) >= probe.cfg.flush_at:
                self._flush_probes()

    def flush_probes(self) -> None:
        """Run deferred sampling and pending probe ground truth now.
        ``stats()`` calls this so the quality block is current; mutations
        call it so buffered queries are judged against the corpus that
        answered them."""
        if getattr(self, "_probe", None) is None:
            return
        try:
            if self._probe_raw:
                self._drain_raw()
            if self._probe_pending:
                self._flush_probes()
        except Exception:
            telem.count("probe_errors_total", engine=self.engine)

    def _flush_probes(self) -> None:
        probe = self._probe
        pending, self._probe_pending = self._probe_pending, []
        if not pending:
            return
        corpus, mask, id_map = self._probe_view(self._probe_filter)
        if corpus is None:  # restored sharded snapshot holds no corpus
            telem.count("probe_skipped_total", engine=self.engine)
            return
        t0 = time.perf_counter()
        m = len(pending)
        kp = max(len(row) for _, row in pending)
        # pad the flush to the fixed pow2 bucket: the shadow scan compiles
        # O(log) programs, same static-shape discipline as serving
        Mp = _bucket(m, floor=min(probe.cfg.flush_at, 8))
        Qs = np.stack([q for q, _ in pending])
        if Mp > m:
            Qs = np.concatenate([Qs, np.repeat(Qs[-1:], Mp - m, axis=0)])
        kg = min(kp, int(corpus.shape[0]))
        _, gt_i = self._probe_gt(jnp.asarray(Qs, jnp.float32), corpus,
                                 mask, kg)
        gt_i = np.asarray(gt_i)[:m]
        srv = np.full((m, kp), -1, np.int64)
        for i, (_, row) in enumerate(pending):
            srv[i, : len(row)] = row
        if id_map is not None:  # live answers come in slot ids -> logical
            ok = (srv >= 0) & (srv < len(id_map))
            srv = np.where(
                ok, np.asarray(id_map)[np.clip(srv, 0, len(id_map) - 1)], -1
            )
        hits, trials = probes_lib.count_hits(srv, gt_i)
        probe.observe(hits, trials)
        est = probe.estimate()
        labels = dict(engine=self.engine, q=self._probe_q_label(), k=kp)
        telem.set_gauge("recall_estimate", est["recall"], **labels)
        telem.set_gauge("recall_ci_low", est["lo"], **labels)
        telem.set_gauge("recall_ci_high", est["hi"], **labels)
        telem.count("probe_total", m, engine=self.engine)
        telem.observe("probe_seconds", time.perf_counter() - t0,
                      engine=self.engine)
        trans = probe.update_slo()
        if trans == "breach":
            self._count_fault("quality_breaches")
            telem.count("quality_degraded_total", engine=self.engine)
            self._set_health("DEGRADED")
        elif trans == "recover" and not self._dead_shards \
                and self.health != "SERVING":
            self._count_fault("recoveries")
            self._set_health("SERVING")

    def _probe_gt(self, Qs, corpus, mask, k: int):
        """Compiled ground-truth scan for probe flushes: ``topk_scan``
        jitted once per (k, metric, maskedness) — eager dispatch of the
        blocked scan costs ~10x the compiled program, which would make
        the shadow path anything but a ~``rate`` tax.  jit's own shape
        cache handles the pow2-padded flush sizes (O(log) programs)."""
        from repro.core import scan as scan_lib

        met = self._probe_metric()
        key = (int(k), met, mask is not None)
        cache = getattr(self, "_probe_gt_cache", None)
        if cache is None:
            cache = self._probe_gt_cache = {}
        fn = cache.get(key)
        if fn is None:
            if mask is None:
                fn = jax.jit(lambda Q, Y: scan_lib.topk_scan(
                    Q, Y, k=key[0], metric=met))
            else:
                fn = jax.jit(lambda Q, Y, v: scan_lib.topk_scan(
                    Q, Y, k=key[0], metric=met, valid=v))
            cache[key] = fn
        return fn(Qs, corpus) if mask is None else fn(Qs, corpus, mask)

    def _probe_view(self, filter):
        """(corpus, valid mask, served-id map) for probe ground truth: the
        filter- and tombstone-correct sub-corpus, in the id space the
        engine answers in (DESIGN.md §17).  Live: the alive logical view
        with slot->logical mapping; filtered: the predicate mask ANDed in;
        sharded: global row ids over the held corpus."""
        from repro.core import filter as filter_lib

        if self.live:
            live = self.index
            corpus = jnp.asarray(live.corpus(), jnp.float32)
            s2l = live.slot_to_logical()
            mask = None
            if filter is not None:
                if isinstance(filter, (np.ndarray, jnp.ndarray)):
                    slot_mask = np.asarray(filter, bool)
                else:
                    slot_mask = np.asarray(filter_lib.resolve_mask(
                        filter, getattr(live, "attrs", None), len(s2l)))
                mask = jnp.asarray(slot_mask[: len(s2l)][s2l >= 0])
            return corpus, mask, s2l
        if self.corpus is None:
            return None, None, None
        n = int(self.corpus.shape[0])
        mask = None
        if filter is not None:
            mask = filter_lib.resolve_mask(
                filter, getattr(self.index, "attrs", None), n)
        return self.corpus, mask, None

    def _probe_metric(self) -> str:
        for obj in (self.index, getattr(self.index, "config", None)):
            met = getattr(obj, "metric", None)
            if isinstance(met, str):
                return met
        return "euclidean"

    def _probe_q_label(self) -> str:
        q = getattr(getattr(self.index, "config", None), "q", None)
        return telem.q_label(q) if q is not None else "na"

    # --------------------------------------------------- roofline profiling
    def capture_roofline(self, *, batch: Optional[int] = None, k: int = 10,
                         budget: Optional[int] = None) -> dict:
        """Profile the current engine's batched search program (DESIGN.md
        §17): one jit around ``index.search`` at the serving bucket shape,
        lowered and compiled AOT, pushed through ``core/profile`` — the
        ``roofline_*`` gauges land in the telemetry registry and the JSON
        block is returned for artifacts."""
        from repro.core import profile as profile_lib

        if self.corpus is None:
            raise RuntimeError(
                "no corpus held (restored sharded snapshot): cannot "
                "synthesize a representative batch to profile"
            )
        if batch is None:  # default: the largest bucket this engine served
            seen = [b for (e, b, _) in self._buckets_seen if e == self.engine]
            batch = max(seen) if seen else 64
        n = int(self.corpus.shape[0])
        Qs = self.corpus[np.arange(int(batch)) % n]
        prof = profile_lib.capture_search(
            self.index, Qs, k=k, budget=budget, engine=self.engine,
            labels={"shards": self.shards},
        )
        return {prof.name: prof.as_row()}

    # ------------------------------------------------------------- mutation
    def _live_index(self):
        if not self.live:
            raise TypeError(
                f"server runs a frozen {self.engine!r} index; build with "
                "live=True (--live) for upsert/delete/compact"
            )
        return self.index

    def upsert(self, vectors, ids=None, attrs=None) -> np.ndarray:
        """Insert / replace rows; visible to the next query (no rebuild).
        ``attrs``: per-row attribute values for filtered search.

        Self-heals an (injected) delta-buffer overflow: compaction drains
        the delta, then the write retries once."""
        live = self._live_index()
        self.flush_probes()  # judge buffered queries against pre-write corpus
        try:
            return live.upsert(vectors, ids=ids, attrs=attrs)
        except chaos_lib.DeltaOverflow:
            self._count_fault("faults")
            self.compact()
            out = live.upsert(vectors, ids=ids, attrs=attrs)
            self._count_fault("recoveries")
            return out

    def delete(self, ids) -> int:
        """Tombstone rows; returns how many were newly marked dead."""
        live = self._live_index()
        self.flush_probes()  # judge buffered queries against pre-delete corpus
        return live.delete(ids)

    def compact(self, mode: Optional[str] = None) -> np.ndarray:
        """Force a generation swap; returns the old->new slot remap.

        A compaction the chaos plan kills dies *before* the atomic publish
        (``LiveIndex.compact`` builds the new generation into locals and
        swaps every reference at once), so the old generation keeps serving
        exact answers — health stays SERVING, only the fault is counted."""
        self.flush_probes()  # slot ids remap at compaction: judge first
        try:
            return self._live_index().compact(mode)
        except chaos_lib.CompactFault:
            self._count_fault("faults")
            raise

    def snapshot(self, path: str) -> str:
        """Persist the serving index (any engine) with ``core/store``; the
        written snapshot is sha256-verified before this returns (a chaos
        ``snapshot`` rule corrupting the write surfaces here, not at some
        future restore)."""
        from repro.core import store as store_lib

        out = store_lib.save(self.index, path)
        try:
            store_lib.verify(path)
        except store_lib.SnapshotCorruption:
            self._count_fault("snapshot_corrupt")
            raise
        return out

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Operator view: latency percentiles over the rolling window
        (the ``LatencyRing``'s most recent batches; ``queries``/``batches``
        stay lifetime totals), plus segment composition when serving a live
        index — delta fill and deleted fraction are the compaction-pressure
        gauges.  With telemetry enabled a ``telemetry`` tree (the registry
        snapshot, DESIGN.md §16) rides along."""
        with self._state_lock:
            # one consistent snapshot of everything worker threads mutate —
            # counters, health, and the latency window (DESIGN.md §18's
            # thread-safety contract, pinned by tests/test_runtime.py)
            out = {
                "engine": self.engine,
                "shards": self.shards,
                "live": self.live,
                "quant": self.quant,
                "queries": self._queries,
                "batches": self._batches,
                "window_batches": len(self._lat),
                "memory_bytes": self.index.memory_bytes(),
                "build_s": round(self.build_s, 3),
            }
            out["health"] = self.health
            if self._dead_shards:
                out["dead_shards"] = sorted(self._dead_shards)
            if any(self.fault_counters.values()):
                out["faults"] = dict(self.fault_counters)
        if self.chaos is not None:
            out["chaos"] = self.chaos.stats()
        if self._probe is not None:
            self.flush_probes()  # quality block reflects every recorded query
            out["quality"] = self._probe.stats()
        qstore = getattr(self.index, "quant", None)
        if qstore is not None:
            # the bandwidth trade at a glance: int8 code bytes the first
            # pass reads vs the f32 corpus bytes it no longer streams
            out["quant_bytes"] = qstore.memory_bytes()
        if len(self._lat):
            lat_s, nq = self._lat.window()
            lat_ms = lat_s * 1e3
            out.update(
                p50_ms=float(np.percentile(lat_ms, 50)),
                p99_ms=float(np.percentile(lat_ms, 99)),
                qps=float(np.sum(nq) / np.sum(lat_s)),
            )
        if telem.enabled():
            out["telemetry"] = telem.summary()
        if self.live:
            seg = self.index.stats()
            out.update(
                generation=seg["generation"], frozen_size=seg["frozen_size"],
                delta_fill=seg["delta_fill"], delta_cap=seg["delta_cap"],
                tombstones=seg["tombstones"], deleted_frac=seg["deleted_frac"],
                n_alive=seg["n_alive"], compactions=seg["compactions"],
            )
        return out

    def metrics_text(self) -> str:
        """The process-wide telemetry registry in Prometheus text
        exposition format — what ``examples/serve_search.py
        --metrics-port`` serves at ``/metrics`` (DESIGN.md §16)."""
        return telem.metrics_text()

    def dump_trace(self, path: str) -> str:
        """Write the telemetry trace ring as Chrome/Perfetto
        ``trace_event`` JSON; returns ``path``."""
        return telem.dump_trace(path)

    def serve(self, batches, k: int = 10, *, budget: Optional[int] = None,
              filter: Optional[dict] = None,
              deadline_ms: Optional[float] = None) -> dict:
        """Drain a queue of query batches; returns latency/throughput stats.

        One warm-up query runs per distinct padded bucket so compile time
        never pollutes the latency percentiles.  ``deadline_ms`` applies
        the per-request degradation controller to every batch; the summary
        then reports how many answers were degraded / missed deadline.
        """
        batches = list(batches)
        if not batches:
            raise ValueError("serve() needs at least one query batch")
        # warm-up/compile once per distinct padded bucket (a trailing partial
        # batch lands in a smaller bucket than the full ones).  Warm-up runs
        # without the deadline so a compile stall cannot trip degradation.
        seen = set()
        for qb in batches:
            b = _bucket(len(qb))
            if b not in seen:
                seen.add(b)
                self.query(qb, k=k, budget=budget, filter=filter, record=False)
        lat, comps, n_q = [], [], 0
        n_degraded = n_missed = n_retries = 0
        for qb in batches:
            t0 = time.perf_counter()
            res = self.query(qb, k=k, budget=budget, filter=filter,
                             deadline_ms=deadline_ms)
            lat.append(time.perf_counter() - t0)
            comps.append(float(res.comparisons.mean()))
            n_q += res.idx.shape[0]
            n_degraded += int(res.degraded)
            n_missed += int(not res.deadline_met)
            n_retries += res.retries
        lat_ms = np.asarray(lat) * 1e3
        out = {
            "engine": self.engine,
            "shards": self.shards,
            "k": k,
            "batches": len(batches),
            "queries": n_q,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "qps": float(n_q / np.sum(lat)),
            "mean_comparisons": float(np.mean(comps)),
            "memory_bytes": self.index.memory_bytes(),
            "build_s": round(self.build_s, 3),
        }
        if deadline_ms is not None or n_degraded or n_retries:
            out.update(deadline_ms=deadline_ms, degraded_batches=n_degraded,
                       deadline_misses=n_missed, retries=n_retries,
                       health=self.health)
        return out


def default_cfg(engine: str, *, budget: Optional[int], rerank: Optional[int],
                train_steps: int = 600, proj_sample: int = 1000) -> dict:
    """Engine-appropriate serving defaults from the shared CLI knobs."""
    cfg: dict = {}
    if engine == "infinity":
        cfg.update(q=math.inf, proj_sample=proj_sample, train_steps=train_steps)
        if rerank is not None:
            cfg["rerank"] = rerank
    elif engine == "ivf_pq" and rerank is not None:
        cfg["rerank"] = rerank
    if budget is not None:
        cfg["budget"] = budget
    return cfg


def demo_attrs(n: int, seed: int = 0) -> dict:
    """Deterministic attribute columns for the synthetic serving corpus:
    one categorical (``category``: c0..c7 round-robin) and one numeric
    (``score``: uniform [0, 1)) — what ``--filter`` predicates run against."""
    rng = np.random.default_rng(seed)
    return {
        "category": [f"c{i % 8}" for i in range(n)],
        "score": rng.uniform(0.0, 1.0, size=n).astype(np.float32),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="infinity",
                    help=f"one of {', '.join(k for k in index_lib.BUILTIN if k not in ('sharded', 'live'))}")
    ap.add_argument("--list-engines", action="store_true",
                    help="print every registered engine key with a one-line "
                         "summary, then exit")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-shard the corpus over this many devices")
    ap.add_argument("--budget", type=int, default=256,
                    help="per-query comparison budget (engine-interpreted)")
    ap.add_argument("--rerank", type=int, default=96,
                    help="two-stage rerank width (infinity / ivf_pq)")
    ap.add_argument("--live", action="store_true",
                    help="mutable serving: upsert/delete/compact on top of the engine")
    ap.add_argument("--quant", action="store_true",
                    help="int8 corpus codes: scan engines read 1 byte/dim "
                         "on the first pass and exactly rerank in f32 "
                         "(the reserved 'quant' registry cfg key)")
    ap.add_argument("--delta-cap", type=int, default=1024,
                    help="live delta-buffer capacity (compaction trigger)")
    ap.add_argument("--snapshot", default=None, metavar="PATH",
                    help="restore the index from PATH if present, else save there after the run")
    ap.add_argument("--filter", default=None, metavar="JSON",
                    help="predicate for the smoke run, e.g. "
                         '\'{"category": {"isin": ["c0", "c1"]}, '
                         '"score": {"range": [0.0, 0.5]}}\' — evaluated '
                         "against the demo attribute columns (category "
                         "c0..c7, score uniform [0,1))")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline: the controller shrinks the "
                         "comparison budget as it drains, retries transient "
                         "faults with capped backoff, and masks a dead "
                         "shard out rather than miss (DESIGN.md §14)")
    ap.add_argument("--chaos", default=None, metavar="JSON",
                    help="arm a deterministic core/chaos FaultPlan, e.g. "
                         '\'{"seed": 0, "rules": [{"site": "search", '
                         '"kind": "latency", "rate": 0.1, "ms": 20}]}\' — '
                         "sites: search/shard/build/compact/delta/snapshot")
    ap.add_argument("--probe-rate", type=float, default=0.0,
                    help="shadow this fraction of queries through the "
                         "exact oracle: online recall estimate + Wilson "
                         "interval in stats()['quality'] (DESIGN.md §17)")
    ap.add_argument("--probe-slo", type=float, default=None,
                    help="recall SLO floor: a sustained probe estimate "
                         "below it walks health to DEGRADED")
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    if args.list_engines:
        for name, summary in index_lib.list_engines().items():
            print(f"{name:10s} {summary}")
        return

    flt = json.loads(args.filter) if args.filter else None
    X = synthetic.make("manifold", args.n + args.queries, seed=0)
    if args.snapshot and os.path.exists(os.path.join(args.snapshot, "meta.json")):
        server = SearchServer.restore(args.snapshot)
        print(f"restored {server.engine} index from {args.snapshot}")
        if flt and getattr(server.index, "attrs", None) is None:
            # the snapshot was saved without attribute columns: attach the
            # deterministic demo columns when that is well-defined —
            # a frozen single-index whose corpus rows ARE the index rows.
            # A live snapshot's corpus() is the logical (alive) view, not
            # slot-aligned, and a sharded snapshot carries no corpus at
            # all: both must be re-saved with attributes instead.
            if server.corpus is None or server.live:
                raise SystemExit(
                    "--filter needs attribute columns, but this snapshot "
                    "was saved without them and they cannot be rebuilt "
                    "for a live/sharded index; re-save it with --filter"
                )
            n = int(server.corpus.shape[0])
            from repro.core import attrs as attrs_lib

            index_lib.attach_store(
                server.index, attrs_lib.AttributeStore.build(demo_attrs(n), n)
            )
    else:
        server = SearchServer(
            X[: args.n], engine=args.engine, shards=args.shards,
            cfg=default_cfg(args.engine, budget=args.budget, rerank=args.rerank),
            live=args.live, delta_cap=args.delta_cap,
            attrs=demo_attrs(args.n) if flt else None, quant=args.quant,
            chaos=json.loads(args.chaos) if args.chaos else None,
            probe=None if args.probe_rate <= 0 else {
                "rate": args.probe_rate,
                **({"slo_floor": args.probe_slo}
                   if args.probe_slo is not None else {}),
            },
        )
    queries = X[args.n:]
    batches = [queries[i : i + args.batch] for i in range(0, len(queries), args.batch)]
    stats = server.serve(batches, k=args.k, budget=args.budget, filter=flt,
                         deadline_ms=args.deadline_ms)
    print(
        f"engine={stats['engine']} shards={stats['shards']} corpus={args.n} "
        f"build={stats['build_s']}s"
        + (" quant=int8" if args.quant else "")
        + (f" filter={args.filter}" if flt else "")
    )
    print(
        f"  {stats['queries']} queries: p50={stats['p50_ms']:.1f}ms "
        f"p99={stats['p99_ms']:.1f}ms qps={stats['qps']:.0f} "
        f"comps/query={stats['mean_comparisons']:.0f}"
    )
    if args.probe_rate > 0:
        qual = server.stats().get("quality", {})
        print(
            f"  quality: probed={qual.get('probed', 0)}/{qual.get('seen', 0)} "
            f"recall~{qual.get('recall_estimate', 0):.3f} "
            f"[{qual.get('ci_low', 0):.3f}, {qual.get('ci_high', 1):.3f}]"
            + (f" slo_floor={args.probe_slo} breached={qual.get('breached')}"
               if args.probe_slo is not None else "")
        )
    if args.deadline_ms is not None or args.chaos:
        print(
            f"  fault: health={server.health} "
            f"degraded={stats.get('degraded_batches', 0)} "
            f"misses={stats.get('deadline_misses', 0)} "
            f"retries={stats.get('retries', 0)}"
            + (f" injected={server.chaos.stats()['injected']}"
               if server.chaos else "")
        )
    if server.live:
        # mutation demo: a churn burst, then the operator's composition view
        rng = np.random.default_rng(1)
        ins = rng.normal(size=(args.batch, X.shape[1])).astype(np.float32)
        new_ids = server.upsert(ins)
        server.delete(new_ids[: args.batch // 4])
        server.query(queries[: args.batch], k=args.k, budget=args.budget)
        s = server.stats()
        print(
            f"  live: gen={s['generation']} frozen={s['frozen_size']} "
            f"delta={s['delta_fill']}/{s['delta_cap']} "
            f"tombstones={s['tombstones']} alive={s['n_alive']} "
            f"compactions={s['compactions']}"
        )
    if args.snapshot and not os.path.exists(os.path.join(args.snapshot, "meta.json")):
        print(f"snapshot -> {server.snapshot(args.snapshot)}")


if __name__ == "__main__":
    main()
