"""Serving driver: the paper's online path (Fig. 18) behind a batch API.

  PYTHONPATH=src python -m repro.launch.serve --engine infinity --n 10000
  PYTHONPATH=src python -m repro.launch.serve --engine ivf_flat --shards 2

``SearchServer`` is registry-driven: any engine key from ``core/index``
(brute / ivf_flat / ivf_pq / nsw / infinity), optionally sharded over the
host's devices, behind one ``query`` method.  Query batches are padded up to
a fixed bucket size so each (bucket, k) pair compiles exactly once — the
static-shape discipline the TPU serving path needs.

For LM serving, ``make_prefill_step`` / ``make_decode_step`` in
train/train_step.py are the hardware entry points exercised by the dry-run
(prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import math
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core.index import SearchResult
from repro.data import synthetic


def _bucket(n: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor) — the padded static batch."""
    b = floor
    while b < n:
        b *= 2
    return b


class SearchServer:
    """Build once, answer batched queries — the deployable object.

    engine / shards select any registered index; ``swap`` rebuilds a
    different engine over the same corpus (hot-swap).  ``query`` pads the
    incoming batch to a power-of-two bucket (repeating the last row) and
    slices the answer back, so arbitrary client batch sizes never trigger
    fresh compilation beyond one per bucket.
    """

    #: serving defaults applied when no cfg is given — the bounded two-stage
    #: operating point (budget/rerank land in the engine's search defaults
    #: where applicable); pass cfg={} to get the engine's own raw defaults.
    DEFAULT_BUDGET = 256
    DEFAULT_RERANK = 96

    def __init__(self, corpus, *, engine: str = "infinity", shards: int = 1,
                 cfg: Optional[dict] = None):
        self.corpus = jnp.asarray(corpus, jnp.float32)
        self.swap(engine, shards=shards, cfg=cfg)

    def swap(self, engine: str, *, shards: int = 1, cfg: Optional[dict] = None) -> None:
        """(Re)build the serving index over the held corpus."""
        if cfg is None:
            cfg = default_cfg(engine, budget=self.DEFAULT_BUDGET,
                              rerank=self.DEFAULT_RERANK)
        t0 = time.perf_counter()
        if shards > 1:
            self.index = index_lib.build(
                "sharded", self.corpus,
                {"engine": engine, "shards": shards, "engine_cfg": dict(cfg or {})},
            )
        else:
            self.index = index_lib.build(engine, self.corpus, cfg)
        self.engine = engine
        self.shards = shards
        self.build_s = time.perf_counter() - t0

    def query(self, batch, k: int = 10, *, budget: Optional[int] = None) -> SearchResult:
        """Answer one query batch; returns host-side SearchResult arrays."""
        batch = jnp.asarray(batch, jnp.float32)
        B = batch.shape[0]
        if B == 0:
            raise ValueError("empty query batch")
        Bp = _bucket(B)
        if Bp > B:  # pad with copies of the last row: static shapes for jit
            batch = jnp.concatenate(
                [batch, jnp.broadcast_to(batch[-1:], (Bp - B, batch.shape[1]))]
            )
        idx, dist, comps = self.index.search(batch, k=k, budget=budget)
        jax.block_until_ready(idx)
        return SearchResult(
            np.asarray(idx)[:B], np.asarray(dist)[:B], np.asarray(comps)[:B]
        )

    def serve(self, batches, k: int = 10, *, budget: Optional[int] = None) -> dict:
        """Drain a queue of query batches; returns latency/throughput stats.

        One warm-up query runs per distinct padded bucket so compile time
        never pollutes the latency percentiles.
        """
        batches = list(batches)
        if not batches:
            raise ValueError("serve() needs at least one query batch")
        # warm-up/compile once per distinct padded bucket (a trailing partial
        # batch lands in a smaller bucket than the full ones)
        seen = set()
        for qb in batches:
            b = _bucket(len(qb))
            if b not in seen:
                seen.add(b)
                self.query(qb, k=k, budget=budget)
        lat, comps, n_q = [], [], 0
        for qb in batches:
            t0 = time.perf_counter()
            res = self.query(qb, k=k, budget=budget)
            lat.append(time.perf_counter() - t0)
            comps.append(float(res.comparisons.mean()))
            n_q += res.idx.shape[0]
        lat_ms = np.asarray(lat) * 1e3
        return {
            "engine": self.engine,
            "shards": self.shards,
            "k": k,
            "batches": len(batches),
            "queries": n_q,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "qps": float(n_q / np.sum(lat)),
            "mean_comparisons": float(np.mean(comps)),
            "memory_bytes": self.index.memory_bytes(),
            "build_s": round(self.build_s, 3),
        }


def default_cfg(engine: str, *, budget: Optional[int], rerank: Optional[int],
                train_steps: int = 600, proj_sample: int = 1000) -> dict:
    """Engine-appropriate serving defaults from the shared CLI knobs."""
    cfg: dict = {}
    if engine == "infinity":
        cfg.update(q=math.inf, proj_sample=proj_sample, train_steps=train_steps)
        if rerank is not None:
            cfg["rerank"] = rerank
    elif engine == "ivf_pq" and rerank is not None:
        cfg["rerank"] = rerank
    if budget is not None:
        cfg["budget"] = budget
    return cfg


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="infinity",
                    help=f"one of {', '.join(index_lib.BUILTIN[:-1])}")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-shard the corpus over this many devices")
    ap.add_argument("--budget", type=int, default=256,
                    help="per-query comparison budget (engine-interpreted)")
    ap.add_argument("--rerank", type=int, default=96,
                    help="two-stage rerank width (infinity / ivf_pq)")
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    X = synthetic.make("manifold", args.n + args.queries, seed=0)
    server = SearchServer(
        X[: args.n], engine=args.engine, shards=args.shards,
        cfg=default_cfg(args.engine, budget=args.budget, rerank=args.rerank),
    )
    queries = X[args.n:]
    batches = [queries[i : i + args.batch] for i in range(0, len(queries), args.batch)]
    stats = server.serve(batches, k=args.k, budget=args.budget)
    print(
        f"engine={stats['engine']} shards={stats['shards']} corpus={args.n} "
        f"build={stats['build_s']}s"
    )
    print(
        f"  {stats['queries']} queries: p50={stats['p50_ms']:.1f}ms "
        f"p99={stats['p99_ms']:.1f}ms qps={stats['qps']:.0f} "
        f"comps/query={stats['mean_comparisons']:.0f}"
    )


if __name__ == "__main__":
    main()
