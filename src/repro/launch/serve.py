"""Serving driver: the paper's online path (Fig. 18) behind a batch API.

  PYTHONPATH=src python -m repro.launch.serve --engine infinity --n 10000
  PYTHONPATH=src python -m repro.launch.serve --engine ivf_flat --shards 2
  PYTHONPATH=src python -m repro.launch.serve --engine nsw --live \
      --delta-cap 512 --snapshot /tmp/idx

``SearchServer`` is registry-driven: any engine key from ``core/index``
(brute / ivf_flat / ivf_pq / nsw / infinity), optionally sharded over the
host's devices, behind one ``query`` method.  Query batches are padded up to
a fixed bucket size so each (bucket, k) pair compiles exactly once — the
static-shape discipline the TPU serving path needs.

``--live`` wraps the engine in the ``core/live`` subsystem: the server
gains ``upsert`` / ``delete`` / ``compact`` / ``snapshot`` operations, and
``stats()`` reports segment composition (frozen size, delta fill,
tombstones, generation) next to the latency percentiles so operators can
see compaction pressure building.  ``--snapshot PATH`` restores the index
from a ``core/store`` snapshot when one exists there, and writes one after
the run otherwise — restart without rebuild.

Filtered search: build the server with ``attrs={column: per-row values}``
and pass ``filter={...}`` (the ``core/filter`` dict sugar) to ``query`` /
``serve`` — every engine then answers only from predicate-passing rows.
``--filter JSON`` smoke-runs it against demo attribute columns, and
``--list-engines`` prints the registry so operators can discover engines
without reading source.

Quantized serving: ``--quant`` (``SearchServer(quant=True)``) adds the
reserved ``quant`` cfg key — the corpus is mirrored as per-dimension int8
codes (``core/quant``) and the scan engines (brute, ivf_flat, infinity's
rerank, the live delta) read 1 byte/dim on the first pass, exactly
reranking a pow2 shortlist in f32.  ``stats()`` reports the code-store
bytes next to memory/QPS so operators see the bandwidth trade.

For LM serving, ``make_prefill_step`` / ``make_decode_step`` in
train/train_step.py are the hardware entry points exercised by the dry-run
(prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import json
import math
import os
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import index as index_lib
from repro.core.index import SearchResult
from repro.data import synthetic


def _bucket(n: int, floor: int = 8) -> int:
    """Smallest power-of-two >= n (>= floor) — the padded static batch."""
    from repro.core.scan import pow2ceil

    return max(floor, pow2ceil(n))


class SearchServer:
    """Build once, answer batched queries — the deployable object.

    engine / shards select any registered index; ``swap`` rebuilds a
    different engine over the same corpus (hot-swap).  ``query`` pads the
    incoming batch to a power-of-two bucket (repeating the last row) and
    slices the answer back, so arbitrary client batch sizes never trigger
    fresh compilation beyond one per bucket.
    """

    #: serving defaults applied when no cfg is given — the bounded two-stage
    #: operating point (budget/rerank land in the engine's search defaults
    #: where applicable); pass cfg={} to get the engine's own raw defaults.
    DEFAULT_BUDGET = 256
    DEFAULT_RERANK = 96

    def __init__(self, corpus, *, engine: str = "infinity", shards: int = 1,
                 cfg: Optional[dict] = None, live: bool = False,
                 delta_cap: int = 1024, attrs: Optional[dict] = None,
                 quant: bool = False):
        self.corpus = jnp.asarray(corpus, jnp.float32)
        self.attr_values = dict(attrs) if attrs else None
        self.quant = bool(quant)
        self.swap(engine, shards=shards, cfg=cfg, live=live, delta_cap=delta_cap)

    def swap(self, engine: str, *, shards: int = 1, cfg: Optional[dict] = None,
             live: Optional[bool] = None, delta_cap: Optional[int] = None,
             quant: Optional[bool] = None) -> None:
        """(Re)build the serving index over the held corpus.  ``live``/
        ``delta_cap``/``quant`` (and the attribute columns given at
        construction) stick across swaps unless overridden."""
        if getattr(self, "corpus", None) is None:
            raise RuntimeError(
                "this server was restored from a snapshot that carries no "
                "corpus (sharded engine state); build a fresh SearchServer "
                "to swap engines"
            )
        if cfg is None:
            cfg = default_cfg(engine, budget=self.DEFAULT_BUDGET,
                              rerank=self.DEFAULT_RERANK)
        self.live = bool(live) if live is not None else getattr(self, "live", False)
        if quant is not None:
            self.quant = bool(quant)
        else:
            self.quant = getattr(self, "quant", False)
        if delta_cap is not None:
            self.delta_cap = int(delta_cap)
        else:
            self.delta_cap = getattr(self, "delta_cap", 1024)
        t0 = time.perf_counter()
        if shards > 1:
            inner, inner_cfg = "sharded", {
                "engine": engine, "shards": shards, "engine_cfg": dict(cfg or {}),
            }
        else:
            inner, inner_cfg = engine, dict(cfg or {})
        attrs = getattr(self, "attr_values", None)
        if self.live:
            top_cfg = {"engine": inner, "engine_cfg": inner_cfg,
                       "delta_cap": self.delta_cap}
            if attrs:
                top_cfg["attrs"] = attrs
            if self.quant:
                top_cfg["quant"] = True
            self.index = index_lib.build("live", self.corpus, top_cfg)
        else:
            if attrs:
                inner_cfg = dict(inner_cfg) | {"attrs": attrs}
            if self.quant:
                inner_cfg = dict(inner_cfg) | {"quant": True}
            self.index = index_lib.build(inner, self.corpus, inner_cfg)
        self.engine = engine
        self.shards = shards
        self.build_s = time.perf_counter() - t0
        self._lat_s: list[float] = []  # per-batch latency record for stats()
        self._queries = 0

    @classmethod
    def restore(cls, path: str) -> "SearchServer":
        """Rebuild a server from a ``core/store`` snapshot — no index build.

        The corpus is recovered where the index carries it (live indexes
        report their logical view; single-device engines hold X); sharded
        snapshots serve fine but hold no rebuildable corpus, so a later
        ``swap()`` raises instead of building on nothing.
        """
        from repro.core import store as store_lib

        index = store_lib.load(path)
        srv = object.__new__(cls)
        srv.index = index

        def unwrap(idx):
            """(engine label, shard count) through live/sharded wrappers."""
            if idx.registry_name == "sharded":
                return idx.engine, idx.n // idx.shard_size
            return getattr(idx, "registry_name", "?"), 1

        srv.live = index.registry_name == "live"
        srv.quant = getattr(index, "quant", None) is not None
        srv.delta_cap = getattr(index, "delta_cap", 1024)
        if srv.live:
            if index.engine == "sharded":
                srv.engine = index.engine_cfg.get("engine", "sharded")
                srv.shards = int(index.engine_cfg.get("shards", 2))
            else:
                srv.engine, srv.shards = index.engine, 1
            corpus = index.corpus()
        else:
            srv.engine, srv.shards = unwrap(index)
            corpus = getattr(index, "X", None)
        srv.corpus = None if corpus is None else jnp.asarray(corpus, jnp.float32)
        # carry restored attribute columns across future swap() rebuilds
        # (live stores are slot-aligned: gather the alive slots, whose
        # order is exactly corpus()'s logical row order)
        store = getattr(index, "attrs", None)
        srv.attr_values = None
        if store is not None and srv.corpus is not None:
            if srv.live:
                alive = np.where(index.slot_to_logical() >= 0)[0]
                srv.attr_values = store.to_values(alive)
            else:
                srv.attr_values = store.to_values(
                    np.arange(int(srv.corpus.shape[0]))
                )
        srv.build_s = 0.0
        srv._lat_s = []
        srv._queries = 0
        return srv

    def query(self, batch, k: int = 10, *, budget: Optional[int] = None,
              filter: Optional[dict] = None, record: bool = True) -> SearchResult:
        """Answer one query batch; returns host-side SearchResult arrays.

        ``filter`` — a ``core/filter`` predicate spec (dict sugar: ``{"shop":
        {"isin": [...]}, "price": {"range": [lo, hi]}}``) evaluated against
        the attribute columns the server was built with; the answer then
        only contains passing rows.  ``record=False`` keeps a warm-up/
        compile call out of the stats() latency record."""
        batch = jnp.asarray(batch, jnp.float32)
        B = batch.shape[0]
        if B == 0:
            raise ValueError("empty query batch")
        Bp = _bucket(B)
        if Bp > B:  # pad with copies of the last row: static shapes for jit
            batch = jnp.concatenate(
                [batch, jnp.broadcast_to(batch[-1:], (Bp - B, batch.shape[1]))]
            )
        t0 = time.perf_counter()
        idx, dist, comps = self.index.search(batch, k=k, budget=budget,
                                             filter=filter)
        jax.block_until_ready(idx)
        if record:
            self._lat_s.append(time.perf_counter() - t0)
            self._queries += B
        return SearchResult(
            np.asarray(idx)[:B], np.asarray(dist)[:B], np.asarray(comps)[:B]
        )

    # ------------------------------------------------------------- mutation
    def _live_index(self):
        if not self.live:
            raise TypeError(
                f"server runs a frozen {self.engine!r} index; build with "
                "live=True (--live) for upsert/delete/compact"
            )
        return self.index

    def upsert(self, vectors, ids=None, attrs=None) -> np.ndarray:
        """Insert / replace rows; visible to the next query (no rebuild).
        ``attrs``: per-row attribute values for filtered search."""
        return self._live_index().upsert(vectors, ids=ids, attrs=attrs)

    def delete(self, ids) -> int:
        """Tombstone rows; returns how many were newly marked dead."""
        return self._live_index().delete(ids)

    def compact(self, mode: Optional[str] = None) -> np.ndarray:
        """Force a generation swap; returns the old->new slot remap."""
        return self._live_index().compact(mode)

    def snapshot(self, path: str) -> str:
        """Persist the serving index (any engine) with ``core/store``."""
        from repro.core import store as store_lib

        return store_lib.save(self.index, path)

    # ---------------------------------------------------------------- stats
    def stats(self) -> dict:
        """Operator view: latency percentiles over every query() so far,
        plus segment composition when serving a live index — delta fill and
        deleted fraction are the compaction-pressure gauges."""
        out = {
            "engine": self.engine,
            "shards": self.shards,
            "live": self.live,
            "quant": self.quant,
            "queries": self._queries,
            "batches": len(self._lat_s),
            "memory_bytes": self.index.memory_bytes(),
            "build_s": round(self.build_s, 3),
        }
        qstore = getattr(self.index, "quant", None)
        if qstore is not None:
            # the bandwidth trade at a glance: int8 code bytes the first
            # pass reads vs the f32 corpus bytes it no longer streams
            out["quant_bytes"] = qstore.memory_bytes()
        if self._lat_s:
            lat_ms = np.asarray(self._lat_s) * 1e3
            out.update(
                p50_ms=float(np.percentile(lat_ms, 50)),
                p99_ms=float(np.percentile(lat_ms, 99)),
                qps=float(self._queries / np.sum(self._lat_s)),
            )
        if self.live:
            seg = self.index.stats()
            out.update(
                generation=seg["generation"], frozen_size=seg["frozen_size"],
                delta_fill=seg["delta_fill"], delta_cap=seg["delta_cap"],
                tombstones=seg["tombstones"], deleted_frac=seg["deleted_frac"],
                n_alive=seg["n_alive"], compactions=seg["compactions"],
            )
        return out

    def serve(self, batches, k: int = 10, *, budget: Optional[int] = None,
              filter: Optional[dict] = None) -> dict:
        """Drain a queue of query batches; returns latency/throughput stats.

        One warm-up query runs per distinct padded bucket so compile time
        never pollutes the latency percentiles.
        """
        batches = list(batches)
        if not batches:
            raise ValueError("serve() needs at least one query batch")
        # warm-up/compile once per distinct padded bucket (a trailing partial
        # batch lands in a smaller bucket than the full ones)
        seen = set()
        for qb in batches:
            b = _bucket(len(qb))
            if b not in seen:
                seen.add(b)
                self.query(qb, k=k, budget=budget, filter=filter, record=False)
        lat, comps, n_q = [], [], 0
        for qb in batches:
            t0 = time.perf_counter()
            res = self.query(qb, k=k, budget=budget, filter=filter)
            lat.append(time.perf_counter() - t0)
            comps.append(float(res.comparisons.mean()))
            n_q += res.idx.shape[0]
        lat_ms = np.asarray(lat) * 1e3
        return {
            "engine": self.engine,
            "shards": self.shards,
            "k": k,
            "batches": len(batches),
            "queries": n_q,
            "p50_ms": float(np.percentile(lat_ms, 50)),
            "p99_ms": float(np.percentile(lat_ms, 99)),
            "qps": float(n_q / np.sum(lat)),
            "mean_comparisons": float(np.mean(comps)),
            "memory_bytes": self.index.memory_bytes(),
            "build_s": round(self.build_s, 3),
        }


def default_cfg(engine: str, *, budget: Optional[int], rerank: Optional[int],
                train_steps: int = 600, proj_sample: int = 1000) -> dict:
    """Engine-appropriate serving defaults from the shared CLI knobs."""
    cfg: dict = {}
    if engine == "infinity":
        cfg.update(q=math.inf, proj_sample=proj_sample, train_steps=train_steps)
        if rerank is not None:
            cfg["rerank"] = rerank
    elif engine == "ivf_pq" and rerank is not None:
        cfg["rerank"] = rerank
    if budget is not None:
        cfg["budget"] = budget
    return cfg


def demo_attrs(n: int, seed: int = 0) -> dict:
    """Deterministic attribute columns for the synthetic serving corpus:
    one categorical (``category``: c0..c7 round-robin) and one numeric
    (``score``: uniform [0, 1)) — what ``--filter`` predicates run against."""
    rng = np.random.default_rng(seed)
    return {
        "category": [f"c{i % 8}" for i in range(n)],
        "score": rng.uniform(0.0, 1.0, size=n).astype(np.float32),
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--engine", default="infinity",
                    help=f"one of {', '.join(k for k in index_lib.BUILTIN if k not in ('sharded', 'live'))}")
    ap.add_argument("--list-engines", action="store_true",
                    help="print every registered engine key with a one-line "
                         "summary, then exit")
    ap.add_argument("--shards", type=int, default=1,
                    help="data-shard the corpus over this many devices")
    ap.add_argument("--budget", type=int, default=256,
                    help="per-query comparison budget (engine-interpreted)")
    ap.add_argument("--rerank", type=int, default=96,
                    help="two-stage rerank width (infinity / ivf_pq)")
    ap.add_argument("--live", action="store_true",
                    help="mutable serving: upsert/delete/compact on top of the engine")
    ap.add_argument("--quant", action="store_true",
                    help="int8 corpus codes: scan engines read 1 byte/dim "
                         "on the first pass and exactly rerank in f32 "
                         "(the reserved 'quant' registry cfg key)")
    ap.add_argument("--delta-cap", type=int, default=1024,
                    help="live delta-buffer capacity (compaction trigger)")
    ap.add_argument("--snapshot", default=None, metavar="PATH",
                    help="restore the index from PATH if present, else save there after the run")
    ap.add_argument("--filter", default=None, metavar="JSON",
                    help="predicate for the smoke run, e.g. "
                         '\'{"category": {"isin": ["c0", "c1"]}, '
                         '"score": {"range": [0.0, 0.5]}}\' — evaluated '
                         "against the demo attribute columns (category "
                         "c0..c7, score uniform [0,1))")
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--queries", type=int, default=256)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()

    if args.list_engines:
        for name, summary in index_lib.list_engines().items():
            print(f"{name:10s} {summary}")
        return

    flt = json.loads(args.filter) if args.filter else None
    X = synthetic.make("manifold", args.n + args.queries, seed=0)
    if args.snapshot and os.path.exists(os.path.join(args.snapshot, "meta.json")):
        server = SearchServer.restore(args.snapshot)
        print(f"restored {server.engine} index from {args.snapshot}")
        if flt and getattr(server.index, "attrs", None) is None:
            # the snapshot was saved without attribute columns: attach the
            # deterministic demo columns when that is well-defined —
            # a frozen single-index whose corpus rows ARE the index rows.
            # A live snapshot's corpus() is the logical (alive) view, not
            # slot-aligned, and a sharded snapshot carries no corpus at
            # all: both must be re-saved with attributes instead.
            if server.corpus is None or server.live:
                raise SystemExit(
                    "--filter needs attribute columns, but this snapshot "
                    "was saved without them and they cannot be rebuilt "
                    "for a live/sharded index; re-save it with --filter"
                )
            n = int(server.corpus.shape[0])
            from repro.core import attrs as attrs_lib

            index_lib.attach_store(
                server.index, attrs_lib.AttributeStore.build(demo_attrs(n), n)
            )
    else:
        server = SearchServer(
            X[: args.n], engine=args.engine, shards=args.shards,
            cfg=default_cfg(args.engine, budget=args.budget, rerank=args.rerank),
            live=args.live, delta_cap=args.delta_cap,
            attrs=demo_attrs(args.n) if flt else None, quant=args.quant,
        )
    queries = X[args.n:]
    batches = [queries[i : i + args.batch] for i in range(0, len(queries), args.batch)]
    stats = server.serve(batches, k=args.k, budget=args.budget, filter=flt)
    print(
        f"engine={stats['engine']} shards={stats['shards']} corpus={args.n} "
        f"build={stats['build_s']}s"
        + (" quant=int8" if args.quant else "")
        + (f" filter={args.filter}" if flt else "")
    )
    print(
        f"  {stats['queries']} queries: p50={stats['p50_ms']:.1f}ms "
        f"p99={stats['p99_ms']:.1f}ms qps={stats['qps']:.0f} "
        f"comps/query={stats['mean_comparisons']:.0f}"
    )
    if server.live:
        # mutation demo: a churn burst, then the operator's composition view
        rng = np.random.default_rng(1)
        ins = rng.normal(size=(args.batch, X.shape[1])).astype(np.float32)
        new_ids = server.upsert(ins)
        server.delete(new_ids[: args.batch // 4])
        server.query(queries[: args.batch], k=args.k, budget=args.budget)
        s = server.stats()
        print(
            f"  live: gen={s['generation']} frozen={s['frozen_size']} "
            f"delta={s['delta_fill']}/{s['delta_cap']} "
            f"tombstones={s['tombstones']} alive={s['n_alive']} "
            f"compactions={s['compactions']}"
        )
    if args.snapshot and not os.path.exists(os.path.join(args.snapshot, "meta.json")):
        print(f"snapshot -> {server.snapshot(args.snapshot)}")


if __name__ == "__main__":
    main()
