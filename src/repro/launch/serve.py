"""Serving driver: the paper's online path (Fig. 18) behind a batch API.

  PYTHONPATH=src python -m repro.launch.serve --n 10000 --port-free
  (in-process demo driver; examples/serve_search.py adds latency stats)

For LM serving, ``make_prefill_step`` / ``make_decode_step`` in
train/train_step.py are the hardware entry points exercised by the dry-run
(prefill_32k / decode_32k / long_500k cells).
"""
from __future__ import annotations

import argparse
import math

import jax.numpy as jnp
import numpy as np

from repro.core.search import IndexConfig, InfinityIndex
from repro.data import synthetic


class SearchServer:
    """Build once, answer batched queries — the deployable object."""

    def __init__(self, corpus, config: IndexConfig | None = None):
        self.index = InfinityIndex.build(jnp.asarray(corpus), config or IndexConfig())

    def query(self, batch, k: int = 10, *, budget: int = 256, rerank: int = 96):
        idx, dist, comps = self.index.search(
            jnp.asarray(batch), k=k, mode="best_first",
            max_comparisons=budget, rerank=rerank,
        )
        return np.asarray(idx), np.asarray(dist), np.asarray(comps)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=5000)
    ap.add_argument("--queries", type=int, default=64)
    ap.add_argument("--k", type=int, default=10)
    args = ap.parse_args()
    X = synthetic.make("manifold", args.n + args.queries, seed=0)
    server = SearchServer(
        X[: args.n],
        IndexConfig(q=math.inf, proj_sample=1000, train_steps=600),
    )
    idx, dist, comps = server.query(X[args.n :], k=args.k)
    print(f"answered {args.queries} queries, k={args.k}, "
          f"mean comparisons={comps.mean():.0f} (corpus {args.n})")


if __name__ == "__main__":
    main()
