"""Dry-run cell builders: (architecture x input shape x mesh) -> a lowered,
shardable step function with abstract inputs (ShapeDtypeStruct — no
allocation; the full configs are only ever exercised this way on CPU).

Every assigned cell resolves here:
  LM:     train_4k -> train_step;  prefill_32k -> prefill;
          decode_32k / long_500k -> one decode step against a full KV cache
  GNN:    full/sampled/batched -> train_step
  RecSys: train_batch -> train_step; serve_* -> forward; retrieval_cand ->
          query-vs-1M top-k scoring
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.configs.base import (
    GNN_SHAPES, LM_SHAPES, RECSYS_SHAPES, GNNShape, LMShape, RecsysShape,
)
from repro.dist import sharding as shard_lib
from repro.models import params as plib
from repro.train import optimizer as opt_lib
from repro.train import train_step as steps

# per-arch training knobs (microbatching keeps live activations bounded;
# adafactor for multi-B-param models; bf16 params >= 100B — DESIGN.md §6)
LM_TRAIN_OPTS = {
    "smollm-135m": dict(microbatches=1, opt="adamw"),
    "deepseek-coder-33b": dict(microbatches=16, opt="adafactor"),
    "gemma-2b": dict(microbatches=4, opt="adamw"),
    "qwen3-moe-235b-a22b": dict(microbatches=16, opt="adafactor", param_dtype="bfloat16"),
    "deepseek-v3-671b": dict(microbatches=16, opt="adafactor", param_dtype="bfloat16"),
}


@dataclasses.dataclass
class BuiltCell:
    arch: str
    shape: str
    step_name: str
    lowered: Any  # jax.stages.Lowered
    meta: dict


def _ns(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )


def _adafactor_spec_tree(decls, dctx):
    def leaf(p):
        spec = tuple(dctx.w_rules.get(n) for n in p.logical)
        if len(p.shape) >= 2:
            return {"vr": P(*spec[:-1]), "vc": P(*(spec[:-2] + spec[-1:]))}
        return {"v": P(*spec)}

    stats = jax.tree_util.tree_map(leaf, decls, is_leaf=plib.is_param)
    return opt_lib.AdafactorState(step=P(), stats=stats)


def _batch_spec(dctx, *extra):
    b = dctx.a_rules.get("batch")
    return P(b, *extra)


def _model_flops_lm(cfg, *, tokens: int, kind: str, kv_len: int = 0) -> float:
    """6·N_active·D for training, 2·N_active per token for inference, plus
    attention score/value flops."""
    n_active = _lm_active_params(cfg)
    mult = 6.0 if kind == "train" else 2.0
    flops = mult * n_active * tokens
    # attention scores+values: 4 flops per (token, ctx position, head, dim);
    # causal halves the train/prefill context, bwd triples training.
    H, Dh = cfg.num_heads, cfg.head_dim
    if cfg.attention == "mla":
        Dh = cfg.mla.qk_nope_head_dim + cfg.mla.qk_rope_head_dim
    per_tok_ctx = kv_len / 2 if kind in ("train", "prefill") else kv_len
    flops += (3.0 if kind == "train" else 1.0) * 4.0 * tokens * per_tok_ctx * H * Dh
    return flops


def _lm_active_decls(cfg):
    from repro.models.transformer import lm_decls

    return lm_decls(cfg)


def _lm_active_params(cfg) -> float:
    from repro.models.transformer import lm_decls

    decls = lm_decls(cfg)
    total = 0.0
    flat = jax.tree_util.tree_flatten_with_path(decls, is_leaf=plib.is_param)[0]
    for path, p in flat:
        size = float(np.prod(p.shape))
        keypath = "/".join(str(k) for k in path)
        if "moe_blocks" in keypath and "mlp" in keypath and (
            "wg" in keypath or "wu" in keypath or "wd" in keypath
        ) and "shared" not in keypath:
            size *= cfg.num_experts_per_tok / cfg.num_experts
        total += size
    return total


# ---------------------------------------------------------------------------
# LM cells
# ---------------------------------------------------------------------------

def build_lm_cell(arch: str, shape: LMShape, mesh, *, mla_absorb: bool = False,
                  overrides: Optional[dict] = None) -> BuiltCell:
    import dataclasses as dc

    from repro.models import transformer

    cfg = configs.get(arch)
    opts = dict(LM_TRAIN_OPTS[arch])
    if overrides:
        opts.update(overrides)
    if shape.kind != "train" and plib.param_count(
        __import__("repro.models.transformer", fromlist=["lm_decls"]).lm_decls(cfg)
    ) > 2e9:
        # serving holds no optimizer state: bf16 weights halve HBM
        opts.setdefault("param_dtype", "bfloat16")
    if "param_dtype" in opts:
        cfg = dc.replace(cfg, param_dtype=opts["param_dtype"])
    B, S = shape.global_batch, shape.seq_len
    dctx = shard_lib.lm_policy(
        cfg, mesh, kind=shape.kind, batch=B,
        moe_impl=opts.get("moe_impl", "gathered"),
    )
    decls = transformer.lm_decls(cfg)
    params_abs = plib.abstract_params(decls)
    pspecs = dctx.shard_w(decls)
    meta = {
        "arch": arch, "shape": shape.name, "family": "lm",
        "params": plib.param_count(decls),
        "active_params": _lm_active_params(cfg),
        "mesh": dict(mesh.shape),
    }

    if shape.kind == "train":
        opt = opt_lib.OPTIMIZERS[opts["opt"]](1e-4)
        ostate_abs = jax.eval_shape(opt.init, params_abs)
        ospecs = (
            _adafactor_spec_tree(decls, dctx)
            if opts["opt"] == "adafactor"
            else opt_lib.AdamWState(step=P(), mu=pspecs, nu=pspecs)
        )
        # per-microbatch batch must stay divisible by the batch shards, or
        # the MoE EP path degrades to the dense fallback
        shards = 1
        for a in dctx.batch_axes:
            shards *= mesh.shape[a]
        mb = min(opts.get("microbatches", 1), max(1, B // max(shards, 1)))
        while mb > 1 and (B % mb or (B // mb) % max(shards, 1)):
            mb -= 1
        opts["microbatches"] = mb
        step = steps.make_train_step(cfg, "lm", opt, dctx, microbatches=mb)
        batch_abs = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
        bspecs = {"tokens": _batch_spec(dctx, None)}
        jitted = jax.jit(
            step,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)),
            out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(params_abs, ostate_abs, batch_abs)
        meta["model_flops"] = _model_flops_lm(cfg, tokens=B * S, kind="train", kv_len=S)
        meta["microbatches"] = opts.get("microbatches", 1)
        return BuiltCell(arch, shape.name, "train_step", lowered, meta)

    if shape.kind == "prefill":
        prefill = steps.make_prefill_step(cfg, dctx, max_len=S)
        tokens_abs = jax.ShapeDtypeStruct((B, S), jnp.int32)
        jitted = jax.jit(
            prefill,
            in_shardings=(_ns(mesh, pspecs), NamedSharding(mesh, _batch_spec(dctx, None))),
        )
        with mesh:
            lowered = jitted.lower(params_abs, tokens_abs)
        meta["model_flops"] = _model_flops_lm(cfg, tokens=B * S, kind="prefill", kv_len=S / 2)
        return BuiltCell(arch, shape.name, "prefill", lowered, meta)

    # decode
    decode = steps.make_decode_step(cfg, dctx, mla_absorb=mla_absorb)
    cache_abs = jax.eval_shape(
        lambda: transformer.init_cache(cfg, B, S, None)
    )
    cspecs = _cache_specs(cfg, dctx)
    tokens_abs = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos_abs = jax.ShapeDtypeStruct((), jnp.int32)
    jitted = jax.jit(
        decode,
        in_shardings=(
            _ns(mesh, pspecs), _ns(mesh, cspecs),
            NamedSharding(mesh, _batch_spec(dctx, None)), NamedSharding(mesh, P()),
        ),
        out_shardings=(None, _ns(mesh, cspecs)),
        donate_argnums=(1,),
    )
    with mesh:
        lowered = jitted.lower(params_abs, cache_abs, tokens_abs, pos_abs)
    meta["model_flops"] = _model_flops_lm(cfg, tokens=B, kind="decode", kv_len=S)
    meta["mla_absorb"] = mla_absorb
    return BuiltCell(arch, shape.name, "decode_step", lowered, meta)


def _cache_specs(cfg, dctx):
    a = dctx.a_rules
    batch = a.get("batch")
    kv_seq = a.get("kv_seq")
    out = {}
    if cfg.attention == "mla":
        mk = lambda: {
            "ckv": P(None, batch, kv_seq, None),
            "krope": P(None, batch, kv_seq, None),
        }
    else:
        kvh = a.get("kv_heads")
        mk = lambda: {
            "k": P(None, batch, kv_seq, kvh, None),
            "v": P(None, batch, kv_seq, kvh, None),
        }
    if cfg.num_dense_layers > 0:
        out["dense"] = mk()
    if cfg.num_moe_layers > 0:
        out["moe"] = mk()
    return out


# ---------------------------------------------------------------------------
# GNN cells
# ---------------------------------------------------------------------------

def _pad_to(n: int, mult: int) -> int:
    return n + (-n) % mult


def build_gnn_cell(arch: str, shape: GNNShape, mesh) -> BuiltCell:
    from repro.models import gnn

    cfg = configs.get(arch)
    dctx = shard_lib.gnn_policy(cfg, mesh)
    n_dev = int(np.prod(list(mesh.shape.values())))
    meta = {"arch": arch, "shape": shape.name, "family": "gnn", "mesh": dict(mesh.shape)}

    if shape.kind == "sampled":
        # fanout-tree static sizes (models/sampler.py)
        f = shape.fanout
        n_nodes = shape.batch_nodes * int(np.prod([x + 1 for x in f]))
        n_edges = shape.batch_nodes * sum(
            int(np.prod(f[: i + 1])) for i in range(len(f))
        )
        d_feat = shape.d_feat
    elif shape.kind == "batched":
        n_nodes = shape.n_nodes * shape.n_graphs
        n_edges = shape.n_edges * shape.n_graphs
        d_feat = shape.d_feat
    else:
        n_nodes, n_edges, d_feat = shape.n_nodes, shape.n_edges, shape.d_feat

    e_pad = _pad_to(n_edges, 2 * n_dev)
    decls = gnn.gcn_decls(cfg, d_feat)
    params_abs = plib.abstract_params(decls)
    pspecs = dctx.shard_w(decls)
    opt = opt_lib.adamw(1e-2)
    ostate_abs = jax.eval_shape(opt.init, params_abs)
    ospecs = opt_lib.AdamWState(step=P(), mu=pspecs, nu=pspecs)
    step = steps.make_train_step(cfg, "gnn", opt, dctx)
    edge_axes = dctx.a_rules.get("edges")
    batch_abs = {
        "x": jax.ShapeDtypeStruct((n_nodes, d_feat), jnp.float32),
        "edges": jax.ShapeDtypeStruct((2, e_pad), jnp.int32),
        "labels": jax.ShapeDtypeStruct((n_nodes,), jnp.int32),
        "label_mask": jax.ShapeDtypeStruct((n_nodes,), jnp.float32),
    }
    bspecs = {
        "x": P(None, None),
        "edges": P(None, edge_axes),
        "labels": P(None),
        "label_mask": P(None),
    }
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)),
        out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), None),
        donate_argnums=(0, 1),
    )
    with mesh:
        lowered = jitted.lower(params_abs, ostate_abs, batch_abs)
    # GCN flops: 2 * E * d_out per conv (messages) + 2 * n * d_in * d_out (xW)
    dims = [d_feat] + [cfg.d_hidden] * (cfg.num_layers - 1) + [cfg.num_classes]
    fl = 0.0
    for i in range(cfg.num_layers):
        fl += 2.0 * n_nodes * dims[i] * dims[i + 1] + 2.0 * n_edges * dims[i + 1]
    meta["model_flops"] = 3.0 * fl  # fwd + bwd(2x)
    meta["n_nodes"], meta["n_edges"] = n_nodes, e_pad
    return BuiltCell(arch, shape.name, "train_step", lowered, meta)


# ---------------------------------------------------------------------------
# RecSys cells
# ---------------------------------------------------------------------------

def build_recsys_cell(arch: str, shape: RecsysShape, mesh) -> BuiltCell:
    from repro.models import recsys

    cfg = configs.get(arch)
    dctx = shard_lib.recsys_policy(cfg, mesh, batch=shape.batch)
    decls = recsys.recsys_decls(cfg)
    params_abs = plib.abstract_params(decls)
    pspecs = dctx.shard_w(decls)
    B, F = shape.batch, cfg.n_sparse
    meta = {
        "arch": arch, "shape": shape.name, "family": "recsys",
        "params": plib.param_count(decls), "mesh": dict(mesh.shape),
    }
    # dense-compute flops per example (interaction + mlp), embedding ignored
    dense_flops = _recsys_dense_flops(cfg)

    if shape.kind == "train":
        opt = opt_lib.adamw(1e-3)
        ostate_abs = jax.eval_shape(opt.init, params_abs)
        ospecs = opt_lib.AdamWState(step=P(), mu=pspecs, nu=pspecs)
        step = steps.make_train_step(cfg, "recsys", opt, dctx)
        batch_abs = {
            "ids": jax.ShapeDtypeStruct((B, F), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B,), jnp.float32),
        }
        bspecs = {"ids": _batch_spec(dctx, None), "labels": _batch_spec(dctx)}
        jitted = jax.jit(
            step,
            in_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspecs)),
            out_shardings=(_ns(mesh, pspecs), _ns(mesh, ospecs), None),
            donate_argnums=(0, 1),
        )
        with mesh:
            lowered = jitted.lower(params_abs, ostate_abs, batch_abs)
        meta["model_flops"] = 3.0 * B * dense_flops
        return BuiltCell(arch, shape.name, "train_step", lowered, meta)

    if shape.kind == "serve":
        serve = steps.make_serve_step(cfg, "recsys", dctx)
        batch_abs = {"ids": jax.ShapeDtypeStruct((B, F), jnp.int32)}
        bspecs = {"ids": _batch_spec(dctx, None)}
        jitted = jax.jit(
            serve, in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs))
        )
        with mesh:
            lowered = jitted.lower(params_abs, batch_abs)
        meta["model_flops"] = 1.0 * B * dense_flops
        return BuiltCell(arch, shape.name, "serve", lowered, meta)

    # retrieval: 1 query vs n_candidates (padded to a 512-divisible power)
    N = _pad_to(shape.n_candidates, 512 * 2048)
    retrieve = steps.make_retrieval_step(cfg, dctx, k=100)
    batch_abs = {
        "ids": jax.ShapeDtypeStruct((B, F), jnp.int32),
        "candidates": jax.ShapeDtypeStruct((N, cfg.embed_dim), jnp.float32),
    }
    cand_axes = dctx.a_rules.get("cand")
    bspecs = {"ids": P(None, None), "candidates": P(cand_axes, None)}
    jitted = jax.jit(
        retrieve, in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs))
    )
    with mesh:
        lowered = jitted.lower(params_abs, batch_abs)
    meta["model_flops"] = 2.0 * B * N * cfg.embed_dim
    meta["n_candidates_padded"] = N
    return BuiltCell(arch, shape.name, "retrieval", lowered, meta)


def _recsys_dense_flops(cfg) -> float:
    F, D = cfg.n_sparse, cfg.embed_dim
    fl = 2.0 * F * D  # FM sum-square trick
    dims = (F * D,) + tuple(cfg.mlp) + ((1,) if cfg.mlp else ())
    for a, b in zip(dims[:-1], dims[1:]):
        fl += 2.0 * a * b
    if cfg.interaction == "cin":
        hs = (F,) + tuple(cfg.cin_layers)
        for hprev, hnext in zip(hs[:-1], hs[1:]):
            fl += 2.0 * hprev * F * D + 2.0 * hnext * hprev * F * D
    if cfg.interaction == "self-attn":
        d_in = D
        for _ in range(cfg.n_attn_layers):
            dh = cfg.n_heads * cfg.d_attn
            fl += 3 * 2.0 * F * d_in * dh + 2 * 2.0 * F * F * dh + 2.0 * F * d_in * dh
            d_in = dh
    return fl


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def all_cells() -> list[tuple[str, str]]:
    out = []
    for arch in ["smollm-135m", "deepseek-coder-33b", "gemma-2b",
                 "qwen3-moe-235b-a22b", "deepseek-v3-671b"]:
        for sh in LM_SHAPES:
            out.append((arch, sh.name))
    for sh in GNN_SHAPES:
        out.append(("gcn-cora", sh.name))
    for arch in ["deepfm", "xdeepfm", "fm", "autoint"]:
        for sh in RECSYS_SHAPES:
            out.append((arch, sh.name))
    return out


def build(arch: str, shape_name: str, mesh, **kw) -> BuiltCell:
    fam = configs.family(arch)
    if fam == "lm":
        shape = next(s for s in LM_SHAPES if s.name == shape_name)
        return build_lm_cell(arch, shape, mesh, **kw)
    if fam == "gnn":
        shape = next(s for s in GNN_SHAPES if s.name == shape_name)
        return build_gnn_cell(arch, shape, mesh)
    if fam == "recsys":
        shape = next(s for s in RECSYS_SHAPES if s.name == shape_name)
        return build_recsys_cell(arch, shape, mesh)
    raise KeyError(arch)
