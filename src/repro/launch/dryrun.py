import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# ^ MUST run before any other import — jax locks the device count on first
# init.  Only the dry-run sets this; tests and benches see 1 device.

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and derive the roofline terms (DESIGN.md §Dry-run).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch smollm-135m --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes

Each cell writes experiments/dryrun/<arch>__<shape>__<mesh>.json with
memory_analysis, cost_analysis, the parsed collective bytes and the three
roofline terms.  --all runs every cell IN-PROCESS sequentially; the
harness-level driver (benchmarks/run_dryrun_all.sh) uses one subprocess per
cell so an OOM/compiler fault in one cell cannot take down the sweep
(fault isolation — same philosophy as the training supervisor).
"""
import argparse
import json
import time
import traceback


def run_cell(arch: str, shape: str, *, multi_pod: bool = False,
             out_dir: str = "experiments/dryrun", overrides=None,
             tag: str = "") -> dict:
    import jax

    from repro.dist import roofline
    from repro.launch import cells
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "x".join(str(s) for s in mesh.devices.shape)
    t0 = time.time()
    kw = {}
    if overrides:
        ov = dict(overrides)
        if "mla_absorb" in ov:
            kw["mla_absorb"] = bool(ov.pop("mla_absorb"))
        if ov:
            kw["overrides"] = ov
    built = cells.build(arch, shape, mesh, **kw)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = built.lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    default_trip = built.meta.get("microbatches", 1)
    coll = roofline.parse_collectives(hlo, default_trip=default_trip)
    # cost_analysis does not scale while-loop (scan) bodies by trip count —
    # hlo_stats walks the loop graph and gives loop-aware flops/bytes.
    stats = roofline.hlo_stats(hlo, default_trip=default_trip)
    loop_cost = {
        "flops": max(stats.flops, float(cost.get("flops", 0.0))),
        "bytes accessed": max(stats.bytes, float(cost.get("bytes accessed", 0.0))),
    }
    chips = int(mesh.size)
    terms = roofline.roofline_terms(
        loop_cost, coll, chips=chips, model_flops=built.meta.get("model_flops")
    )
    result = {
        "arch": arch,
        "shape": shape,
        "mesh": mesh_name,
        "step": built.step_name,
        "ok": True,
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes_per_device": mem.argument_size_in_bytes,
            "output_bytes_per_device": mem.output_size_in_bytes,
            "temp_bytes_per_device": mem.temp_size_in_bytes,
            "alias_bytes_per_device": mem.alias_size_in_bytes,
            "peak_estimate_gib": round(
                (mem.argument_size_in_bytes + mem.output_size_in_bytes
                 + mem.temp_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3,
            ),
        },
        "cost": {
            "xla_flops": float(cost.get("flops", 0.0)),
            "xla_bytes": float(cost.get("bytes accessed", 0.0)),
            "loop_aware_flops": stats.flops,
            "loop_aware_bytes": stats.bytes,
            "dot_ops": stats.dot_count,
        },
        "collectives": {
            "total_bytes": coll.total_bytes,
            "by_kind": coll.bytes_by_kind,
            "loop_trips": coll.loop_trip_counts,
        },
        "roofline": terms,
        "meta": {k: v for k, v in built.meta.items() if k != "mesh"},
    }
    os.makedirs(out_dir, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    path = os.path.join(out_dir, f"{arch}__{shape}__{mesh_name}{suffix}.json")
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    ap.add_argument("--mla-absorb", action="store_true")
    ap.add_argument("--set", action="append", default=[],
                    help="cell override key=value (microbatches=8, "
                         "param_dtype=bfloat16, moe_impl=zero3, opt=adamw)")
    args = ap.parse_args()

    from repro.launch import cells as cells_lib

    todo = cells_lib.all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    failures = []
    for arch, shape in todo:
        for mp in meshes:
            label = f"{arch} x {shape} x {'2x16x16' if mp else '16x16'}"
            try:
                overrides = {}
                if args.mla_absorb:
                    overrides["mla_absorb"] = True
                for kv in args.set:
                    key, val = kv.split("=", 1)
                    overrides[key] = int(val) if val.isdigit() else val
                overrides = overrides or None
                r = run_cell(arch, shape, multi_pod=mp, out_dir=args.out_dir,
                             overrides=overrides, tag=args.tag)
                rf = r["roofline"]
                print(
                    f"OK  {label}: compile={r['compile_s']}s "
                    f"mem/dev={r['memory']['peak_estimate_gib']}GiB "
                    f"t_comp={rf['t_compute_s']:.2e}s t_mem={rf['t_memory_s']:.2e}s "
                    f"t_coll={rf['t_collective_s']:.2e}s dom={rf['dominant']}",
                    flush=True,
                )
            except Exception as e:  # noqa: BLE001 — sweep must survive
                failures.append((label, repr(e)))
                print(f"FAIL {label}: {e!r}", flush=True)
                traceback.print_exc()
    if failures:
        raise SystemExit(f"{len(failures)} cells failed: {[f[0] for f in failures]}")


if __name__ == "__main__":
    main()
