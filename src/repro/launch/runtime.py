"""Overload-safe async serving runtime (DESIGN.md §18).

``SearchServer`` answers one batch at a time; this module puts a bounded,
deadline-aware admission queue and a continuous batcher in front of it so
the server survives *overload* the way §14 made it survive *faults*:

* **Bounded admission** — ``submit`` enqueues one request; when the queue
  is at capacity it raises ``Rejected(reason="capacity")`` with a
  ``retry_after_s`` hint instead of letting the queue grow without bound.
  While the circuit breaker is open, submits fast-fail with
  ``Rejected(reason="breaker")`` and the breaker's remaining cooldown.
* **Continuous batching** — a single batcher thread drains the queue into
  shape-pow2 buckets keyed ``(k, filter-view)``; a bucket flushes when it
  reaches ``max_batch`` or its oldest request has waited ``flush_ms``
  (size-or-timeout, TGI-style), landing in the exact jit cache the
  synchronous path compiled (``SearchServer.query`` pads to the same
  pow2 buckets).
* **Load shedding** — requests whose deadline lapsed while queued are shed
  *before* compute with an explicit ``outcome="shed_expired"`` result;
  dispatch order within a bucket is EDF (earliest deadline first), so
  under pressure the requests most likely to still make their deadline
  run first.  Nothing is ever dropped silently: every submitted request
  resolves to a ``ServedResult`` or a raised error.
* **Watermark backpressure** — queue depth above ``high_watermark`` walks
  the §14 health machine SERVING→DEGRADED and tightens the comparison
  budget down ``core/backoff.degraded_budget``'s pow2 ladder (the
  paper's q/budget anytime knob: less work per query, lower recall,
  higher throughput); below ``low_watermark`` the budget and health
  recover.
* **Circuit breaking** — ``core/backoff.CircuitBreaker`` wraps engine
  dispatch: consecutive dispatch faults or whole-batch deadline misses
  trip it open, queued work fast-fails (``outcome="shed_breaker"``)
  instead of piling onto a sick engine, and a half-open probe closes it
  once the engine answers in time again.  The ``core/chaos`` plan's
  ``slow_search`` site fires at dispatch, so breaker + shedding are
  deterministically chaos-testable.

``start_http_front`` exposes the runtime over a real socket (stdlib
ThreadingHTTPServer, mirroring ``examples/serve_search.py``'s metrics
port): POST /search answers 200, or 429/503 + ``Retry-After`` on
admission rejection, or 504 when the request was shed expired — the
multi-process load path ``benchmarks/bench_load.py`` and the roadmap's
multi-process client fixture drive.

Telemetry (when ``core/telemetry`` is enabled): ``queue_depth``,
``batch_fill``, ``queue_wait_seconds``, ``admission_total{outcome=}``,
``shed_total{reason=}``, ``batches_formed_total``, ``breaker_state``,
``breaker_trips_total``.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import threading
import time
from concurrent.futures import Future
from typing import Optional

import numpy as np

from repro.core import backoff as backoff_lib
from repro.core import chaos as chaos_lib
from repro.core import probes as probes_lib
from repro.core import telemetry as telem
from repro.launch.serve import SearchServer, ServedResult

#: ``batch_fill`` histogram buckets: batch sizes, not seconds — registered
#: explicitly so ``telem.observe`` reuses them instead of latency buckets.
BATCH_FILL_BUCKETS = (1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0)


class Rejected(Exception):
    """Admission refused — the request never entered the queue.

    ``reason`` is ``"capacity"`` (queue full) or ``"breaker"`` (circuit
    open); ``retry_after_s`` is the client backoff hint (maps to the HTTP
    ``Retry-After`` header in ``start_http_front``)."""

    def __init__(self, reason: str, retry_after_s: float = 0.0):
        super().__init__(f"rejected: {reason} (retry after "
                         f"{retry_after_s:.3f}s)")
        self.reason = reason
        self.retry_after_s = float(retry_after_s)


class Ticket:
    """Handle for one submitted request — ``result()`` blocks for its
    ``ServedResult`` (or re-raises the dispatch error)."""

    __slots__ = ("_future", "seq")

    def __init__(self, future: Future, seq: int):
        self._future = future
        self.seq = seq

    def result(self, timeout: Optional[float] = None) -> ServedResult:
        return self._future.result(timeout)

    def done(self) -> bool:
        return self._future.done()


class _Request:
    __slots__ = ("q", "k", "dl_abs", "deadline_ms", "filter", "t_submit",
                 "seq", "future")

    def __init__(self, q, k, dl_abs, deadline_ms, filter, seq):
        self.q = q
        self.k = k
        self.dl_abs = dl_abs  # absolute monotonic expiry, or None
        self.deadline_ms = deadline_ms
        self.filter = filter
        self.t_submit = time.monotonic()
        self.seq = seq
        self.future: Future = Future()


def _edf_key(r: _Request):
    """EDF order: earliest absolute deadline first; undeadlined requests
    last; FIFO (submit sequence) within ties."""
    return (r.dl_abs if r.dl_abs is not None else float("inf"), r.seq)


class BoundedQueue:
    """Bounded request queue, bucketed by jit-compatible shape key.

    Buckets key on ``(k, filter-view)`` — requests that can share one
    padded dispatch.  ``offer`` is O(1) and refuses (returns False) at
    capacity; ``take_batch`` blocks until some bucket is flush-ready
    (reached ``max_batch``, or its oldest request waited ``flush_s``) and
    returns it EDF-ordered.  Capacity counts requests across all buckets.
    """

    def __init__(self, capacity: int):
        self.capacity = int(capacity)
        self._lock = threading.Lock()
        self._nonempty = threading.Condition(self._lock)
        self._buckets: dict = {}  # key -> list[_Request]
        self._depth = 0

    def depth(self) -> int:
        with self._lock:
            return self._depth

    def offer(self, key, req: _Request) -> bool:
        with self._nonempty:
            if self._depth >= self.capacity:
                return False
            self._buckets.setdefault(key, []).append(req)
            self._depth += 1
            self._nonempty.notify()
            return True

    def take_batch(self, max_batch: int, flush_s: float, *,
                   poll_s: float = 0.05):
        """Next flush-ready bucket as ``(key, [requests])`` EDF-ordered,
        or None after ``poll_s`` of emptiness (lets the caller check its
        running flag)."""
        with self._nonempty:
            while True:
                if self._depth == 0:
                    if not self._nonempty.wait(timeout=poll_s):
                        return None
                    continue
                now = time.monotonic()
                # the bucket whose head has waited longest decides the
                # flush clock (continuous batching's size-or-timeout)
                key = min(self._buckets,
                          key=lambda kk: self._buckets[kk][0].t_submit)
                reqs = self._buckets[key]
                waited = now - reqs[0].t_submit
                if len(reqs) >= max_batch or waited >= flush_s:
                    reqs.sort(key=_edf_key)
                    take, rest = reqs[:max_batch], reqs[max_batch:]
                    if rest:
                        self._buckets[key] = rest
                    else:
                        del self._buckets[key]
                    self._depth -= len(take)
                    return key, take
                self._nonempty.wait(timeout=max(1e-4, flush_s - waited))

    def drain(self) -> list:
        """Remove and return every queued request (shutdown path)."""
        with self._lock:
            out = [r for reqs in self._buckets.values() for r in reqs]
            self._buckets.clear()
            self._depth = 0
            return out


@dataclasses.dataclass
class OverloadPolicy:
    """The runtime's knobs (DESIGN.md §18).

    ``capacity`` bounds queued requests (admission rejects beyond it);
    ``max_batch`` / ``flush_ms`` are the continuous batcher's
    size-or-timeout; ``high_watermark`` / ``low_watermark`` are queue-fill
    fractions walking health DEGRADED/SERVING and driving the
    ``degraded_budget`` pow2 ladder; ``budget`` is the full-headroom
    comparison budget (None = engine default, ladder disabled);
    ``breaker_*`` parameterize the dispatch circuit breaker."""

    capacity: int = 1024
    max_batch: int = 64
    flush_ms: float = 2.0
    high_watermark: float = 0.5
    low_watermark: float = 0.25
    budget: Optional[int] = None
    budget_floor: int = 8
    breaker_trip: int = 5
    breaker_cooldown_s: float = 0.5
    breaker_cooldown_cap_s: float = 8.0


class ServingRuntime:
    """The async front for a ``SearchServer``: bounded admission,
    continuous batching, shedding, backpressure, circuit breaking.

    Lifecycle: construct over a built server, ``start()`` the batcher
    thread, ``submit()`` from any number of client threads, ``stop()`` to
    drain (leftover queued requests resolve ``outcome="shed_shutdown"`` —
    never silently dropped).  ``submit`` before ``start`` is allowed and
    simply queues (tests use this to fill the queue deterministically).
    """

    def __init__(self, server: SearchServer,
                 policy: Optional[OverloadPolicy] = None):
        self.server = server
        self.policy = policy or OverloadPolicy()
        self.queue = BoundedQueue(self.policy.capacity)
        self.breaker = backoff_lib.CircuitBreaker(
            trip=self.policy.breaker_trip,
            cooldown_s=self.policy.breaker_cooldown_s,
            cooldown_cap_s=self.policy.breaker_cooldown_cap_s)
        self._seq = itertools.count()
        self._lock = threading.Lock()
        self._thread: Optional[threading.Thread] = None
        self._running = False
        self._ewma_batch_s = self.policy.flush_ms / 1e3  # service-time est.
        self.counters = {
            "admitted": 0, "rejected_capacity": 0, "rejected_breaker": 0,
            "completed": 0, "shed_expired": 0, "shed_breaker": 0,
            "shed_shutdown": 0, "dispatch_faults": 0, "batches": 0,
        }
        if telem.enabled():
            telem.REGISTRY.histogram(
                "batch_fill", "requests per formed batch",
                buckets=BATCH_FILL_BUCKETS)

    # ------------------------------------------------------------ lifecycle
    def start(self) -> "ServingRuntime":
        with self._lock:
            if self._running:
                return self
            self._running = True
            self._thread = threading.Thread(
                target=self._batcher, name="serving-batcher", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        with self._lock:
            self._running = False
            t, self._thread = self._thread, None
        if t is not None:
            t.join(timeout=10.0)
        for r in self.queue.drain():
            self._count("shed_shutdown")
            telem.count("shed_total", reason="shutdown")
            self._resolve_shed(r, "shed_shutdown", deadline_met=True)
        self._gauge_depth()

    # ------------------------------------------------------------ admission
    def submit(self, q, k: int = 10, *, deadline_ms: Optional[float] = None,
               filter: Optional[dict] = None) -> Ticket:
        """Enqueue one query vector ``q`` (shape (d,)).  Raises
        ``Rejected`` when the queue is full or the breaker is open."""
        ra = self.breaker.retry_after_s()
        if ra > 0.0:
            self._count("rejected_breaker")
            telem.count("admission_total", outcome="rejected_breaker")
            raise Rejected("breaker", retry_after_s=ra)
        dl_abs = (None if deadline_ms is None
                  else time.monotonic() + float(deadline_ms) / 1e3)
        req = _Request(np.asarray(q, np.float32), int(k), dl_abs,
                       deadline_ms, filter, next(self._seq))
        key = (req.k, probes_lib.view_key(filter))
        if not self.queue.offer(key, req):
            # hint: time to drain one batch's worth of the current depth
            est = self._ewma_batch_s * max(
                1.0, self.queue.depth() / max(1, self.policy.max_batch))
            self._count("rejected_capacity")
            telem.count("admission_total", outcome="rejected_capacity")
            raise Rejected("capacity", retry_after_s=est)
        self._count("admitted")
        telem.count("admission_total", outcome="admitted")
        self._gauge_depth()
        return Ticket(req.future, req.seq)

    # ------------------------------------------------------------- batcher
    def _batcher(self) -> None:
        while True:
            with self._lock:
                if not self._running:
                    return
            got = self.queue.take_batch(self.policy.max_batch,
                                        self.policy.flush_ms / 1e3)
            if got is None:
                continue
            key, reqs = got
            self._gauge_depth()
            try:
                self._dispatch(key, reqs)
            except BaseException as e:  # never kill the batcher silently
                for r in reqs:
                    if not r.future.done():
                        r.future.set_exception(e)

    def _dispatch(self, key, reqs: list) -> None:
        k = key[0]
        now = time.monotonic()
        live = []
        for r in reqs:
            if r.dl_abs is not None and now >= r.dl_abs:
                # shed BEFORE compute: the deadline lapsed in the queue
                self._count("shed_expired")
                telem.count("shed_total", reason="expired")
                self._resolve_shed(r, "shed_expired", deadline_met=False)
            else:
                live.append(r)
        if not live:
            return
        if not self.breaker.allow():
            for r in live:
                self._count("shed_breaker")
                telem.count("shed_total", reason="breaker")
                self._resolve_shed(r, "shed_breaker", deadline_met=True)
            return
        telem.count("batches_formed_total", k=k)
        telem.observe("batch_fill", float(len(live)))
        self._count("batches")
        eff_budget = self._backpressure()
        # batch deadline = tightest remaining among its members (EDF put
        # the tightest first, so the whole bucket shares its pressure)
        rem = [(r.dl_abs - now) * 1e3 for r in live if r.dl_abs is not None]
        batch_dl = min(rem) if rem else None
        batch = np.stack([r.q for r in live])
        t0 = time.monotonic()
        ok = True
        tripped = False
        try:
            if self.server.chaos is not None:
                # the runtime-level fault site: latency rules stall the
                # dispatch thread (queue grows, deadlines slip), fault
                # rules raise — both feed the breaker deterministically
                self.server.chaos.on_slow_search()
            res = self.server.query(batch, k=k, budget=eff_budget,
                                    filter=live[0].filter,
                                    deadline_ms=batch_dl)
        except Exception as e:
            ok = False
            self._count("dispatch_faults")
            telem.count("dispatch_faults_total")
            tripped = self.breaker.record(False)
            for r in live:
                r.future.set_exception(e)
        else:
            done = time.monotonic()
            n_met = 0
            for i, r in enumerate(live):
                met = r.dl_abs is None or done <= r.dl_abs
                n_met += met
                queue_ms = (t0 - r.t_submit) * 1e3
                r.future.set_result(ServedResult(
                    res.idx[i:i + 1], res.dist[i:i + 1],
                    res.comparisons[i:i + 1], degraded=res.degraded,
                    shards_answered=res.shards_answered,
                    shards_total=res.shards_total, retries=res.retries,
                    deadline_met=met, queue_ms=queue_ms, outcome="ok"))
                self._count("completed")
                telem.count("admission_total", outcome="completed")
                telem.observe("queue_wait_seconds", queue_ms / 1e3)
            # a whole-batch deadline miss counts as a dispatch failure:
            # N consecutive ones mean the engine can't keep up — trip
            ok = n_met == len(live)
            tripped = self.breaker.record(ok)
        if tripped:
            telem.count("breaker_trips_total")
        self._ewma_batch_s = (0.8 * self._ewma_batch_s
                              + 0.2 * (time.monotonic() - t0))
        telem.set_gauge("breaker_state", self.breaker.state_code(),
                        engine=self.server.engine)

    def _backpressure(self) -> Optional[int]:
        """Queue fill -> effective comparison budget + health walk.

        Headroom (1 - fill) feeds the §14 ``degraded_budget`` pow2 ladder:
        above ``high_watermark`` the server is marked DEGRADED and each
        further halving of headroom halves the budget (the q/anytime knob
        — faster, lower-recall answers drain the queue); back below
        ``low_watermark`` with no dead shards, SERVING and the full
        budget return."""
        fill = self.queue.depth() / max(1, self.policy.capacity)
        if fill >= self.policy.high_watermark:
            self.server._set_health("DEGRADED")
        elif (fill <= self.policy.low_watermark
              and not self.server._dead_shards
              and self.server.health == "DEGRADED"):
            self.server._set_health("SERVING")
        return backoff_lib.degraded_budget(
            self.policy.budget, 1.0 - fill, floor=self.policy.budget_floor)

    # ------------------------------------------------------------- helpers
    def _resolve_shed(self, r: _Request, outcome: str,
                      deadline_met: bool) -> None:
        k = r.k
        r.future.set_result(ServedResult(
            np.full((1, k), -1, np.int32),
            np.full((1, k), np.inf, np.float32),
            np.zeros((1,), np.int32), deadline_met=deadline_met,
            queue_ms=(time.monotonic() - r.t_submit) * 1e3,
            outcome=outcome))

    def _count(self, key: str, n: int = 1) -> None:
        with self._lock:
            self.counters[key] += n

    def _gauge_depth(self) -> None:
        telem.set_gauge("queue_depth", self.queue.depth(),
                        engine=self.server.engine)

    def stats(self) -> dict:
        with self._lock:
            out = dict(self.counters)
        out.update(
            queue_depth=self.queue.depth(),
            capacity=self.policy.capacity,
            breaker_state=self.breaker.state,
            breaker_trips=self.breaker.trips,
            health=self.server.health,
        )
        return out


# ---------------------------------------------------------------------------
# HTTP front: the real socket path (roadmap item 3's multi-process fixture)
# ---------------------------------------------------------------------------

def start_http_front(runtime: ServingRuntime, port: int = 0,
                     *, result_timeout_s: float = 30.0):
    """Serve the runtime over HTTP on ``port`` (0 = ephemeral); returns the
    ``ThreadingHTTPServer`` (``.server_address[1]`` is the bound port,
    ``.shutdown()`` stops it).

    * ``POST /search`` body ``{"q": [...], "k": 10, "deadline_ms": 50}``
      → 200 with idx/dist/outcome/queue_ms, or 429 (+``Retry-After``) at
      capacity, 503 (+``Retry-After``) while the breaker is open, 504 when
      the request was shed (deadline expired in queue / breaker opened
      before dispatch).
    * ``GET /healthz`` → health + queue depth + breaker state.
    * ``GET /metrics`` → Prometheus exposition (``core/telemetry``).
    """
    from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

    class Handler(BaseHTTPRequestHandler):
        def log_message(self, *a):  # quiet: the load generator hammers this
            pass

        def _json(self, code: int, obj: dict, headers=()):
            body = json.dumps(obj).encode()
            self.send_response(code)
            self.send_header("Content-Type", "application/json")
            self.send_header("Content-Length", str(len(body)))
            for hk, hv in headers:
                self.send_header(hk, hv)
            self.end_headers()
            self.wfile.write(body)

        def do_GET(self):
            if self.path == "/healthz":
                self._json(200, runtime.stats())
            elif self.path == "/metrics":
                body = telem.metrics_text().encode()
                self.send_response(200)
                self.send_header("Content-Type",
                                 "text/plain; version=0.0.4")
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)
            else:
                self._json(404, {"error": "not found"})

        def do_POST(self):
            if self.path != "/search":
                self._json(404, {"error": "not found"})
                return
            n = int(self.headers.get("Content-Length", 0))
            try:
                payload = json.loads(self.rfile.read(n) or b"{}")
                q = np.asarray(payload["q"], np.float32)
            except (KeyError, ValueError) as e:
                self._json(400, {"error": f"bad request: {e}"})
                return
            try:
                ticket = runtime.submit(
                    q, int(payload.get("k", 10)),
                    deadline_ms=payload.get("deadline_ms"),
                    filter=payload.get("filter"))
            except Rejected as e:
                code = 429 if e.reason == "capacity" else 503
                self._json(code, {"outcome": f"rejected_{e.reason}",
                                  "retry_after_s": e.retry_after_s},
                           headers=(("Retry-After",
                                     f"{max(e.retry_after_s, 1e-3):.3f}"),))
                return
            try:
                r = ticket.result(timeout=result_timeout_s)
            except Exception as e:
                self._json(500, {"error": repr(e)})
                return
            if r.outcome != "ok":
                self._json(504, {"outcome": r.outcome,
                                 "queue_ms": r.queue_ms})
                return
            self._json(200, {
                "outcome": "ok",
                "idx": np.asarray(r.idx)[0].tolist(),
                "dist": np.asarray(r.dist)[0].tolist(),
                "comparisons": int(np.asarray(r.comparisons)[0]),
                "degraded": bool(r.degraded),
                "deadline_met": bool(r.deadline_met),
                "queue_ms": float(r.queue_ms),
            })

    httpd = ThreadingHTTPServer(("127.0.0.1", int(port)), Handler)
    threading.Thread(target=httpd.serve_forever, daemon=True,
                     name="serving-http").start()
    return httpd
