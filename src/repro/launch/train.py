"""Training driver with the fault-tolerance supervisor in the loop.

CPU-scale by default (reduced configs); pass --full under the dry-run
device count to exercise the production mesh.  The loop structure is the
deployment one: data sharded per host, async checkpoints, NaN guard,
straggler deadline, elastic restart hook.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch deepfm --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro import configs
from repro.data.tokens import TokenStream, recsys_batch
from repro.models import params as plib
from repro.train import checkpoint as ckpt_lib
from repro.train import optimizer as opt_lib
from repro.train import train_step as steps
from repro.train.fault import Supervisor, SupervisorConfig


def build(arch: str, *, reduced: bool = True, seq_len: int = 64, batch: int = 8):
    fam = configs.family(arch)
    cfg = configs.get_reduced(arch) if reduced else configs.get(arch)
    rng = jax.random.PRNGKey(0)
    if fam == "lm":
        from repro.models import transformer

        decls = transformer.lm_decls(cfg)
        params = plib.init_params(rng, decls)
        opt = opt_lib.adamw(3e-4)
        step = jax.jit(steps.make_train_step(cfg, "lm", opt))
        stream = TokenStream(cfg.vocab_size, seq_len, batch)
        batches = lambda t: {
            k: jax.numpy.asarray(v) for k, v in stream.batch(t).items()
        }
    elif fam == "recsys":
        from repro.models import recsys

        decls = recsys.recsys_decls(cfg)
        params = plib.init_params(rng, decls)
        opt = opt_lib.adamw(1e-3)
        step = jax.jit(steps.make_train_step(cfg, "recsys", opt))
        vocabs = cfg.vocabs[: cfg.n_sparse]
        batches = lambda t: {
            k: jax.numpy.asarray(v)
            for k, v in recsys_batch(t, batch, vocabs).items()
        }
    elif fam == "gnn":
        from repro.models import gnn

        n, d, E = 200, 16, 800
        g = np.random.default_rng(0)
        decls = gnn.gcn_decls(cfg, d)
        params = plib.init_params(rng, decls)
        opt = opt_lib.adamw(1e-2)
        step = jax.jit(steps.make_train_step(cfg, "gnn", opt))
        x = g.normal(size=(n, d)).astype(np.float32)
        edges = g.integers(0, n, size=(2, E)).astype(np.int32)
        labels = g.integers(0, cfg.num_classes, size=n).astype(np.int32)
        fixed = {
            "x": jax.numpy.asarray(x),
            "edges": jax.numpy.asarray(edges),
            "labels": jax.numpy.asarray(labels),
        }
        batches = lambda t: fixed
    else:
        raise KeyError(arch)
    state = opt.init(params)
    return params, state, step, batches


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    params, state, step_fn, batches = build(
        args.arch, seq_len=args.seq_len, batch=args.batch
    )
    sup = Supervisor(SupervisorConfig())
    saver = ckpt_lib.AsyncCheckpointer(args.ckpt_dir)
    start = 0
    if args.resume and ckpt_lib.latest_step(args.ckpt_dir) is not None:
        (params, state), start = ckpt_lib.restore(args.ckpt_dir, (params, state))
        print(f"resumed from step {start}")

    for t in range(start, args.steps):
        t0 = time.time()
        batch = batches(t)
        params, state, metrics = step_fn(params, state, batch)
        loss = float(metrics["loss"])
        dt = time.time() - t0
        verdict = sup.observe_loss(loss)
        if verdict == "restore":
            (params, state), t = ckpt_lib.restore(args.ckpt_dir, (params, state))
            print(f"[fault] non-finite loss run — restored step {t}")
            continue
        if verdict == "skip":
            print(f"[fault] step {t}: non-finite loss, update skipped")
            continue
        pace = sup.observe_step_time(dt)
        if pace != "ok":
            print(f"[fault] step {t}: {pace} ({dt:.2f}s)")
        if t % 10 == 0 or t == args.steps - 1:
            print(f"step {t}: loss={loss:.4f} ({dt*1e3:.0f} ms)")
        if args.ckpt_every and t and t % args.ckpt_every == 0:
            saver.save(t, (params, state))
    saver.wait()
    print("done")


if __name__ == "__main__":
    main()
