"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches jax device
state.  The dry-run (and only the dry-run) forces 512 host platform devices
before calling these.
"""
from __future__ import annotations

import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """(16, 16) = 256 chips per pod; multi_pod adds a leading 2-pod axis."""
    import jax

    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) == n:
        return jax.make_mesh(shape, axes)
    if len(devices) < n:
        raise RuntimeError(
            f"need {n} devices for mesh {shape}, have {len(devices)} — "
            "run under launch/dryrun.py which forces 512 host devices"
        )
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_test_mesh(shape=(2, 2), axes=("data", "model")):
    """Small mesh for unit tests (requires forced host devices)."""
    import jax

    n = int(np.prod(shape))
    from jax.sharding import Mesh

    return Mesh(np.asarray(jax.devices()[:n]).reshape(shape), axes)
