"""Minimal stand-in for the ``hypothesis`` property-testing library.

The container this repo runs in does not ship ``hypothesis`` and installing
packages is off-limits, so this shim provides the tiny API surface the test
suite actually uses (``given`` with keyword strategies, ``settings``,
``strategies.integers`` / ``strategies.sampled_from``).  Examples are drawn
deterministically (seeded by the test name) so failures reproduce across
runs.

If the real package is ever installed, this module defers to it: it scans
``sys.path`` beyond its own directory and re-exports the genuine
implementation when found, so the stub cannot shadow a later install.
"""
from __future__ import annotations

import functools
import os
import random
import sys
import zlib
from typing import Any, Callable, Sequence


def _defer_to_real_package() -> bool:
    """Load the genuine hypothesis from any sys.path entry other than this
    file's directory; re-export it from this module if present."""
    here = os.path.dirname(os.path.abspath(__file__))
    for entry in sys.path:
        if not entry or os.path.abspath(entry) == here:
            continue
        init = os.path.join(entry, "hypothesis", "__init__.py")
        if not os.path.exists(init):
            continue
        import importlib.util

        spec = importlib.util.spec_from_file_location(
            "hypothesis", init,
            submodule_search_locations=[os.path.dirname(init)],
        )
        mod = importlib.util.module_from_spec(spec)
        sys.modules["hypothesis"] = mod
        spec.loader.exec_module(mod)
        globals().update(
            {k: v for k, v in vars(mod).items() if not k.startswith("__")}
        )
        return True
    return False


_REAL = _defer_to_real_package()


class _Strategy:
    def __init__(self, draw: Callable[[random.Random], Any], desc: str):
        self._draw = draw
        self.desc = desc

    def example(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"<strategy {self.desc}>"


class _Strategies:
    @staticmethod
    def integers(min_value: int, max_value: int) -> _Strategy:
        return _Strategy(
            lambda rng: rng.randint(min_value, max_value),
            f"integers({min_value}, {max_value})",
        )

    @staticmethod
    def sampled_from(elements: Sequence[Any]) -> _Strategy:
        elems = list(elements)
        return _Strategy(lambda rng: rng.choice(elems), f"sampled_from({elems!r})")

    @staticmethod
    def floats(min_value: float = 0.0, max_value: float = 1.0, **_: Any) -> _Strategy:
        return _Strategy(
            lambda rng: rng.uniform(min_value, max_value),
            f"floats({min_value}, {max_value})",
        )

    @staticmethod
    def booleans() -> _Strategy:
        return _Strategy(lambda rng: rng.random() < 0.5, "booleans()")


strategies = _Strategies()


class settings:
    """Decorator recording (max_examples, deadline); consumed by ``given``."""

    def __init__(self, max_examples: int = 20, deadline: Any = None, **_: Any):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn: Callable) -> Callable:
        fn._stub_settings = self
        return fn


def given(**strategy_kwargs: _Strategy) -> Callable:
    """Run the wrapped test on deterministically drawn examples."""

    def decorate(fn: Callable) -> Callable:
        seed = zlib.crc32(fn.__qualname__.encode())

        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> None:
            # settings may be applied above @given (the common ordering), in
            # which case it lands on this wrapper — resolve at call time
            cfg = getattr(wrapper, "_stub_settings", None) or getattr(
                fn, "_stub_settings", None
            )
            n_examples = cfg.max_examples if cfg is not None else 20
            rng = random.Random(seed)
            accepted = 0
            for attempt in range(n_examples * 10):
                if accepted >= n_examples:
                    break
                drawn = {k: s.example(rng) for k, s in strategy_kwargs.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                    accepted += 1
                except _Rejected:  # assume() failed: redraw, don't fail
                    continue
                except Exception as e:  # noqa: BLE001 - annotate and re-raise
                    raise AssertionError(
                        f"{fn.__name__} failed on example {accepted}: {drawn!r}"
                    ) from e

        # pytest must not see the strategy kwargs as fixtures
        import inspect

        wrapper.__signature__ = inspect.Signature()  # type: ignore[attr-defined]
        return wrapper

    return decorate


HealthCheck = type("HealthCheck", (), {"all": staticmethod(lambda: [])})


class _Rejected(Exception):
    """Raised by assume() to discard the current example."""


def assume(condition: bool) -> None:
    if not condition:
        raise _Rejected()
